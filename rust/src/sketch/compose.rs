//! Theorem-1 closure operations over sketches: estimate weighted sums,
//! differences, and (via hash composition at construction time) products
//! of collision-probability losses using multiple sketches.
//!
//! Addition/subtraction is a *query-time* operation: build one sketch per
//! constituent loss, estimate each, and combine the estimates linearly.
//! Multiplication happens at *hash* time (see [`crate::lsh::compose`]) —
//! a sketch built on the composed hash directly estimates the product.

use super::storm::StormSketch;

/// A weighted combination of STORM estimates:
/// `L(q) = sum_j w_j * risk_j(q)` — the paper's f1 (addition/subtraction
/// closure), exposed as a first-class estimator so optimizers can run on
/// composite losses (e.g. loss + lambda * regularizer-sketch).
pub struct CompositeRisk<'a> {
    terms: Vec<(f64, &'a StormSketch)>,
}

impl<'a> CompositeRisk<'a> {
    pub fn new() -> Self {
        CompositeRisk { terms: Vec::new() }
    }

    /// Add a weighted term.
    pub fn with(mut self, weight: f64, sketch: &'a StormSketch) -> Self {
        if let Some((_, first)) = self.terms.first() {
            assert_eq!(first.dim(), sketch.dim(), "composite terms must share dim");
        }
        self.terms.push((weight, sketch));
        self
    }

    /// Number of terms.
    pub fn len(&self) -> usize {
        self.terms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.terms.is_empty()
    }

    /// Estimate the combined risk at a (unit-ball) query.
    pub fn estimate(&self, q: &[f64]) -> f64 {
        self.terms
            .iter()
            .map(|(w, s)| w * s.estimate_risk(q))
            .sum()
    }

    /// Estimate with automatic query rescaling.
    pub fn estimate_scaled(&self, q: &[f64]) -> f64 {
        self.terms
            .iter()
            .map(|(w, s)| w * s.estimate_risk_scaled(q))
            .sum()
    }
}

impl Default for CompositeRisk<'_> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StormConfig;
    use crate::testing::{assert_close, gen_ball_point};
    use crate::util::rng::Xoshiro256;

    fn sketch_of(data: &[Vec<f64>], seed: u64) -> StormSketch {
        let cfg = StormConfig { rows: 600, power: 4, saturating: true, ..Default::default() };
        let mut sk = StormSketch::new(cfg, 3, seed);
        for z in data {
            sk.insert(z);
        }
        sk
    }

    #[test]
    fn linear_combination_of_estimates() {
        let mut rng = Xoshiro256::new(1);
        let d1: Vec<Vec<f64>> = (0..100).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
        let d2: Vec<Vec<f64>> = (0..100).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
        let s1 = sketch_of(&d1, 10);
        let s2 = sketch_of(&d2, 11);
        let q = gen_ball_point(&mut rng, 3, 0.8);
        let c = CompositeRisk::new().with(1.0, &s1).with(-0.5, &s2);
        assert_eq!(c.len(), 2);
        assert_close(
            c.estimate(&q),
            s1.estimate_risk(&q) - 0.5 * s2.estimate_risk(&q),
            1e-12,
        );
    }

    #[test]
    fn difference_of_identical_sketches_is_zero() {
        let mut rng = Xoshiro256::new(2);
        let d: Vec<Vec<f64>> = (0..50).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
        let s1 = sketch_of(&d, 20);
        let s2 = sketch_of(&d, 20); // same seed + data => identical counters
        let q = gen_ball_point(&mut rng, 3, 0.8);
        let c = CompositeRisk::new().with(1.0, &s1).with(-1.0, &s2);
        assert_close(c.estimate(&q), 0.0, 1e-12);
    }

    #[test]
    fn scaled_variant_finite_for_big_queries() {
        let mut rng = Xoshiro256::new(3);
        let d: Vec<Vec<f64>> = (0..50).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
        let s = sketch_of(&d, 30);
        let c = CompositeRisk::new().with(2.0, &s);
        assert!(c.estimate_scaled(&[5.0, -5.0, 5.0]).is_finite());
    }

    #[test]
    #[should_panic]
    fn mismatched_dims_rejected() {
        let cfg = StormConfig::default();
        let s1 = StormSketch::new(cfg, 3, 1);
        let s2 = StormSketch::new(cfg, 4, 1);
        let _ = CompositeRisk::new().with(1.0, &s1).with(1.0, &s2);
    }
}
