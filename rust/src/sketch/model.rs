//! The task-generic sketch model layer.
//!
//! The paper's point is *end-to-end* ERM on the edge for both regression
//! (Theorem 2) and max-margin classification (Theorem 3); compressive
//! statistical learning frames the same idea as one sketch API serving
//! many learning tasks. This module is that API:
//!
//! * [`RiskSketch`] — the unified insert / estimate / batch / snapshot /
//!   delta / merge surface every sketch model exposes. The whole
//!   device → fleet → driver pipeline is written against this trait, so
//!   adding a task means implementing it once — no per-type plumbing.
//! * [`StormModel`] — the concrete task dispatcher: construct from a
//!   [`StormConfig`] whose `task` field (config key `[storm] task`, CLI
//!   `--task`) selects the paired-PRP regression sketch or the
//!   single-arm margin classifier.
//!
//! **Conventions.** Streams carry *examples* `z = [x, y]` of length
//! `example_dim = d + 1` for both tasks (regression hashes the full
//! augmented vector; classification folds the ±1 label into the hash
//! sign). Risk queries take the *augmented parameter* `theta~ =
//! [theta, -1]`, also length `d + 1`; the classifier reads only the
//! leading `d` coordinates (its hyperplane passes through the origin).
//! This keeps one optimizer loop ([`crate::optim::RiskOracle`]) driving
//! every task and backend.
//!
//! Both tasks hash through the family-dispatched
//! [`crate::lsh::bank::HashBank`], so `[storm] hash_family`
//! (dense / sparse / hadamard — see [`crate::lsh`]) and the SIMD dense
//! kernels apply uniformly here; no task-specific plumbing.

use super::counters::CounterGrid;
use super::delta::{SketchDelta, SketchSnapshot};
use super::storm::{StormClassifierSketch, StormSketch};
use crate::config::{StormConfig, Task};
use crate::lsh::bank::HashBank;
use crate::lsh::query::{CandidateSet, QueryEngine};
use crate::util::mathx::norm2;

/// Common behaviour of the trainable count-sketch models in this crate
/// (supersedes the old `Sketch` trait, which the pipeline ignored).
///
/// All implementors are *mergeable summaries*: `merge_from` of two models
/// built with the same configuration and seeds equals the model of the
/// concatenated streams (exactly — counts are integers), and the
/// epoch-tagged delta algebra ([`SketchDelta`]) factors any merge into
/// per-round increments, which is what the fleet protocol ships.
pub trait RiskSketch: Send + Sized {
    /// Construct a model for `cfg.task`. `example_dim` is the streamed
    /// example length `d + 1` ( features + label ); `seed` fixes the
    /// shared hash family fleet-wide.
    fn build(cfg: StormConfig, example_dim: usize, seed: u64) -> Self;

    /// The sketch configuration (with `task` normalized to this model's
    /// actual task).
    fn config(&self) -> StormConfig;

    /// The learning task this model estimates risk for.
    fn task(&self) -> Task {
        self.config().task
    }

    /// Shared hash-family seed.
    fn seed(&self) -> u64;

    /// Streamed example length `d + 1`.
    fn example_dim(&self) -> usize;

    /// Examples ingested (including everything merged in).
    fn count(&self) -> u64;

    /// The underlying counter grid.
    fn grid(&self) -> &CounterGrid;

    /// The fused hash bank this model queries through. The incremental
    /// query engine ([`QueryEngine`]) binds to it, so anything holding a
    /// `RiskSketch` can build the rank-1 candidate path without knowing
    /// the task.
    fn bank(&self) -> &HashBank;

    /// Counter memory in bytes, width-true.
    fn bytes(&self) -> usize {
        self.grid().bytes()
    }

    /// Ingest one example `z = [x, y]` (length [`Self::example_dim`]).
    fn insert(&mut self, z: &[f64]);

    /// Fused batch ingest — bit-identical counters to sequential
    /// [`Self::insert`] calls (property-tested per implementor).
    fn insert_batch(&mut self, batch: &[Vec<f64>]);

    /// Estimated task risk at the augmented parameter `theta~ =
    /// [theta, -1]` (length [`Self::example_dim`]), rescaled into the
    /// unit ball as needed.
    fn estimate_risk_scaled(&self, theta_tilde: &[f64]) -> f64;

    /// Batched risk estimation: one estimate per candidate, in order,
    /// written into `out` (cleared first); bit-identical to per-candidate
    /// [`Self::estimate_risk_scaled`], with scratch reuse instead of
    /// per-candidate allocation.
    fn estimate_risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>);

    /// Serve a whole optimizer candidate set through the rank-1
    /// incremental query engine: one estimate per probe, in order,
    /// written into `out` (cleared first). `engine` must be bound to
    /// [`Self::bank`]'s geometry (build it with
    /// `QueryEngine::new(model.bank())`). Buckets — and hence estimates —
    /// match dense materialization exactly except at measure-zero
    /// floating-point hyperplane ties (see [`crate::lsh::query`]).
    fn estimate_risk_candidates(
        &self,
        engine: &mut QueryEngine,
        set: &CandidateSet,
        out: &mut Vec<f64>,
    );

    /// Freeze the current counters for a later [`Self::delta_since`].
    fn snapshot(&self) -> SketchSnapshot;

    /// The increments accumulated since `snap`, tagged with `epoch`.
    fn delta_since(&self, snap: &SketchSnapshot, epoch: u64) -> SketchDelta;

    /// Apply a remote delta (geometry, task, seed and dim must match;
    /// widths may differ — narrow deltas widen exactly).
    fn apply_delta(&mut self, delta: &SketchDelta);

    /// Merge another model built with identical configuration/seeds.
    fn merge_from(&mut self, other: &Self);

    /// Exponentially decay the counters *and* the example count to
    /// `keep_permille / 1000` of their value (integer floor at the native
    /// width) — the round-boundary down-weighting for non-stationary
    /// streams (`[privacy] decay_keep`). 1000 is an exact no-op; smaller
    /// values make the sketch a recency-weighted summary, trading the
    /// exact merge algebra for drift tracking.
    fn decay(&mut self, keep_permille: u16);

    /// Overwrite this model's counters and example count from arena
    /// bytes (little-endian cells at the grid's native width). This is
    /// the load half of the SoA fleet executor's state swap: a worker
    /// keeps ONE scratch model (the hash bank is the expensive part and
    /// is identical for every device built from the same config + seed)
    /// and pages per-device counters in and out of one contiguous
    /// allocation. `src` length must equal [`Self::bytes`].
    fn load_state(&mut self, src: &[u8], count: u64);

    /// Write this model's counters to arena bytes at native width (the
    /// store half of the swap; the example count travels separately in
    /// the executor's SoA column).
    fn store_state(&self, dst: &mut [u8]);

    /// Downcast to the regression sketch when this model is one (the
    /// regression-only paths — linear partition warm starts, the XLA
    /// query backend — gate on this).
    fn as_regression(&self) -> Option<&StormSketch> {
        None
    }
}

impl RiskSketch for StormSketch {
    fn build(cfg: StormConfig, example_dim: usize, seed: u64) -> Self {
        assert_ne!(
            cfg.task,
            Task::Classification,
            "regression-typed pipeline given a classification config — use StormModel"
        );
        StormSketch::new(cfg, example_dim, seed)
    }

    fn config(&self) -> StormConfig {
        StormSketch::config(self)
    }

    fn seed(&self) -> u64 {
        StormSketch::seed(self)
    }

    fn example_dim(&self) -> usize {
        StormSketch::dim(self)
    }

    fn count(&self) -> u64 {
        StormSketch::count(self)
    }

    fn grid(&self) -> &CounterGrid {
        StormSketch::grid(self)
    }

    fn bank(&self) -> &HashBank {
        StormSketch::bank(self)
    }

    fn insert(&mut self, z: &[f64]) {
        StormSketch::insert(self, z)
    }

    fn insert_batch(&mut self, batch: &[Vec<f64>]) {
        StormSketch::insert_batch(self, batch)
    }

    fn estimate_risk_scaled(&self, theta_tilde: &[f64]) -> f64 {
        StormSketch::estimate_risk_scaled(self, theta_tilde)
    }

    fn estimate_risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        StormSketch::estimate_risk_batch(self, candidates, out)
    }

    fn estimate_risk_candidates(
        &self,
        engine: &mut QueryEngine,
        set: &CandidateSet,
        out: &mut Vec<f64>,
    ) {
        StormSketch::estimate_risk_candidates(self, engine, set, out)
    }

    fn snapshot(&self) -> SketchSnapshot {
        StormSketch::snapshot(self)
    }

    fn delta_since(&self, snap: &SketchSnapshot, epoch: u64) -> SketchDelta {
        StormSketch::delta_since(self, snap, epoch)
    }

    fn apply_delta(&mut self, delta: &SketchDelta) {
        StormSketch::apply_delta(self, delta)
    }

    fn merge_from(&mut self, other: &Self) {
        StormSketch::merge_from(self, other)
    }

    fn decay(&mut self, keep_permille: u16) {
        StormSketch::decay(self, keep_permille)
    }

    fn load_state(&mut self, src: &[u8], count: u64) {
        let (grid, cnt) = self.parts_mut();
        grid.load_native(src);
        *cnt = count;
    }

    fn store_state(&self, dst: &mut [u8]) {
        StormSketch::grid(self).store_native(dst);
    }

    fn as_regression(&self) -> Option<&StormSketch> {
        Some(self)
    }
}

impl RiskSketch for StormClassifierSketch {
    fn build(cfg: StormConfig, example_dim: usize, seed: u64) -> Self {
        assert!(example_dim >= 2, "classification needs at least one feature plus the label");
        StormClassifierSketch::new(cfg, example_dim - 1, seed)
    }

    fn config(&self) -> StormConfig {
        StormClassifierSketch::config(self)
    }

    fn seed(&self) -> u64 {
        StormClassifierSketch::seed(self)
    }

    fn example_dim(&self) -> usize {
        self.feature_dim() + 1
    }

    fn count(&self) -> u64 {
        StormClassifierSketch::count(self)
    }

    fn grid(&self) -> &CounterGrid {
        StormClassifierSketch::grid(self)
    }

    fn bank(&self) -> &HashBank {
        StormClassifierSketch::bank(self)
    }

    fn insert(&mut self, z: &[f64]) {
        let d = self.feature_dim();
        assert_eq!(z.len(), d + 1, "insert dim mismatch (examples are [x, y])");
        self.insert_labelled(&z[..d], z[d]);
    }

    fn insert_batch(&mut self, batch: &[Vec<f64>]) {
        StormClassifierSketch::insert_batch(self, batch)
    }

    fn estimate_risk_scaled(&self, theta_tilde: &[f64]) -> f64 {
        let d = self.feature_dim();
        assert_eq!(theta_tilde.len(), d + 1, "query dim mismatch");
        StormClassifierSketch::estimate_risk_scaled(self, &theta_tilde[..d])
    }

    fn estimate_risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(candidates.len());
        if candidates.is_empty() {
            return;
        }
        let d = self.feature_dim();
        let radius = crate::data::scale::query_radius();
        // One scratch buffer across candidates — zero per-candidate
        // allocation, results bit-identical to scalar
        // `estimate_risk_scaled` (property-tested).
        let mut scaled = vec![0.0; d];
        for q in candidates {
            assert_eq!(q.len(), d + 1, "query dim mismatch");
            let theta = &q[..d];
            let n = norm2(theta);
            let est = if n <= radius {
                self.fused_estimate(theta)
            } else {
                for (s, v) in scaled.iter_mut().zip(theta) {
                    *s = v * radius / n;
                }
                self.fused_estimate(&scaled)
            };
            out.push(est);
        }
    }

    fn estimate_risk_candidates(
        &self,
        engine: &mut QueryEngine,
        set: &CandidateSet,
        out: &mut Vec<f64>,
    ) {
        StormClassifierSketch::estimate_risk_candidates(self, engine, set, out)
    }

    fn snapshot(&self) -> SketchSnapshot {
        StormClassifierSketch::snapshot(self)
    }

    fn delta_since(&self, snap: &SketchSnapshot, epoch: u64) -> SketchDelta {
        StormClassifierSketch::delta_since(self, snap, epoch)
    }

    fn apply_delta(&mut self, delta: &SketchDelta) {
        StormClassifierSketch::apply_delta(self, delta)
    }

    fn merge_from(&mut self, other: &Self) {
        StormClassifierSketch::merge_from(self, other)
    }

    fn decay(&mut self, keep_permille: u16) {
        StormClassifierSketch::decay(self, keep_permille)
    }

    fn load_state(&mut self, src: &[u8], count: u64) {
        let (grid, cnt) = self.parts_mut();
        grid.load_native(src);
        *cnt = count;
    }

    fn store_state(&self, dst: &mut [u8]) {
        StormClassifierSketch::grid(self).store_native(dst);
    }
}

/// The task dispatcher: one constructor for every learning task the
/// sketch family supports, selected by [`StormConfig::task`]. This is
/// what the driver (and anything else that reads a run config) should
/// instantiate; the concrete types remain available for task-specific
/// code and tests.
pub enum StormModel {
    Regression(StormSketch),
    Classification(StormClassifierSketch),
}

impl StormModel {
    /// Dispatch a constructor call on `cfg.task`.
    pub fn new(cfg: StormConfig, example_dim: usize, seed: u64) -> StormModel {
        match cfg.task {
            Task::Regression => StormModel::Regression(StormSketch::new(cfg, example_dim, seed)),
            Task::Classification => {
                assert!(
                    example_dim >= 2,
                    "classification needs at least one feature plus the label"
                );
                StormModel::Classification(StormClassifierSketch::new(cfg, example_dim - 1, seed))
            }
        }
    }

    /// The classifier variant, when this model is one.
    pub fn as_classifier(&self) -> Option<&StormClassifierSketch> {
        match self {
            StormModel::Classification(c) => Some(c),
            StormModel::Regression(_) => None,
        }
    }
}

macro_rules! dispatch {
    ($self:expr, $m:pat => $body:expr) => {
        match $self {
            StormModel::Regression($m) => $body,
            StormModel::Classification($m) => $body,
        }
    };
}

impl RiskSketch for StormModel {
    fn build(cfg: StormConfig, example_dim: usize, seed: u64) -> Self {
        StormModel::new(cfg, example_dim, seed)
    }

    fn config(&self) -> StormConfig {
        dispatch!(self, m => m.config())
    }

    fn seed(&self) -> u64 {
        dispatch!(self, m => RiskSketch::seed(m))
    }

    fn example_dim(&self) -> usize {
        dispatch!(self, m => m.example_dim())
    }

    fn count(&self) -> u64 {
        dispatch!(self, m => m.count())
    }

    fn grid(&self) -> &CounterGrid {
        dispatch!(self, m => m.grid())
    }

    fn bank(&self) -> &HashBank {
        dispatch!(self, m => RiskSketch::bank(m))
    }

    fn insert(&mut self, z: &[f64]) {
        dispatch!(self, m => RiskSketch::insert(m, z))
    }

    fn insert_batch(&mut self, batch: &[Vec<f64>]) {
        dispatch!(self, m => RiskSketch::insert_batch(m, batch))
    }

    fn estimate_risk_scaled(&self, theta_tilde: &[f64]) -> f64 {
        dispatch!(self, m => RiskSketch::estimate_risk_scaled(m, theta_tilde))
    }

    fn estimate_risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        dispatch!(self, m => RiskSketch::estimate_risk_batch(m, candidates, out))
    }

    fn estimate_risk_candidates(
        &self,
        engine: &mut QueryEngine,
        set: &CandidateSet,
        out: &mut Vec<f64>,
    ) {
        dispatch!(self, m => RiskSketch::estimate_risk_candidates(m, engine, set, out))
    }

    fn snapshot(&self) -> SketchSnapshot {
        dispatch!(self, m => RiskSketch::snapshot(m))
    }

    fn delta_since(&self, snap: &SketchSnapshot, epoch: u64) -> SketchDelta {
        dispatch!(self, m => RiskSketch::delta_since(m, snap, epoch))
    }

    fn apply_delta(&mut self, delta: &SketchDelta) {
        dispatch!(self, m => RiskSketch::apply_delta(m, delta))
    }

    fn merge_from(&mut self, other: &Self) {
        match (self, other) {
            (StormModel::Regression(a), StormModel::Regression(b)) => a.merge_from(b),
            (StormModel::Classification(a), StormModel::Classification(b)) => a.merge_from(b),
            _ => panic!("merge: task mismatch"),
        }
    }

    fn decay(&mut self, keep_permille: u16) {
        dispatch!(self, m => RiskSketch::decay(m, keep_permille))
    }

    fn load_state(&mut self, src: &[u8], count: u64) {
        dispatch!(self, m => RiskSketch::load_state(m, src, count))
    }

    fn store_state(&self, dst: &mut [u8]) {
        dispatch!(self, m => RiskSketch::store_state(m, dst))
    }

    fn as_regression(&self) -> Option<&StormSketch> {
        match self {
            StormModel::Regression(r) => Some(r),
            StormModel::Classification(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen_ball_point;
    use crate::util::rng::Xoshiro256;

    fn labelled_stream(rng: &mut Xoshiro256, n: usize, d: usize) -> Vec<Vec<f64>> {
        (0..n)
            .map(|i| {
                let mut z = gen_ball_point(rng, d, 0.9);
                z.push(if i % 2 == 0 { 1.0 } else { -1.0 });
                z
            })
            .collect()
    }

    #[test]
    fn model_dispatches_on_task() {
        let reg = StormModel::new(StormConfig::default(), 4, 1);
        assert!(reg.as_regression().is_some());
        assert!(reg.as_classifier().is_none());
        assert_eq!(reg.task(), Task::Regression);
        assert_eq!(reg.example_dim(), 4);

        let cfg = StormConfig { task: Task::Classification, ..Default::default() };
        let clf = StormModel::new(cfg, 4, 1);
        assert!(clf.as_regression().is_none());
        assert!(clf.as_classifier().is_some());
        assert_eq!(clf.task(), Task::Classification);
        assert_eq!(clf.example_dim(), 4, "example dim is uniform across tasks");
        assert_eq!(clf.as_classifier().unwrap().feature_dim(), 3);
    }

    #[test]
    fn classification_model_inserts_match_the_concrete_classifier() {
        let cfg = StormConfig {
            rows: 12,
            power: 3,
            saturating: true,
            task: Task::Classification,
            ..Default::default()
        };
        let mut rng = Xoshiro256::new(3);
        let stream = labelled_stream(&mut rng, 50, 3);
        let mut model = StormModel::new(cfg, 4, 7);
        model.insert_batch(&stream);
        let mut concrete = StormClassifierSketch::new(cfg, 3, 7);
        for z in &stream {
            concrete.insert_labelled(&z[..3], z[3]);
        }
        assert_eq!(model.grid().counts_u32(), concrete.grid().counts_u32());
        assert_eq!(model.count(), 50);
        // Scalar trait inserts agree with the batch path.
        let mut scalar = StormModel::new(cfg, 4, 7);
        for z in &stream {
            scalar.insert(z);
        }
        assert_eq!(scalar.grid().counts_u32(), model.grid().counts_u32());
    }

    #[test]
    fn classifier_risk_batch_matches_scalar_bitwise() {
        let cfg = StormConfig {
            rows: 40,
            power: 2,
            saturating: true,
            task: Task::Classification,
            ..Default::default()
        };
        let mut rng = Xoshiro256::new(5);
        let stream = labelled_stream(&mut rng, 200, 4);
        let mut model = StormModel::new(cfg, 5, 9);
        model.insert_batch(&stream);
        // Mix of in-ball candidates and far-outside ones (rescale path);
        // candidates are augmented [theta, -1].
        let mut cands: Vec<Vec<f64>> = Vec::new();
        for i in 0..16 {
            let mut t = gen_ball_point(&mut rng, 4, 0.8);
            if i % 3 == 0 {
                for v in &mut t {
                    *v *= 7.0;
                }
            }
            t.push(-1.0);
            cands.push(t);
        }
        let mut out = Vec::new();
        model.estimate_risk_batch(&cands, &mut out);
        assert_eq!(out.len(), cands.len());
        for (q, got) in cands.iter().zip(&out) {
            let want = model.estimate_risk_scaled(q);
            assert!(got.to_bits() == want.to_bits(), "fused {got} != scalar {want}");
        }
        // Empty model estimates are zero.
        let empty = StormModel::new(cfg, 5, 9);
        let mut out = Vec::new();
        empty.estimate_risk_batch(&cands[..1], &mut out);
        assert_eq!(out, vec![0.0]);
    }

    #[test]
    fn model_rounds_of_deltas_reassemble_the_classifier() {
        // The classifier rides the same snapshot/delta algebra as the
        // regression sketch: per-epoch deltas applied at a leader equal
        // the device's cumulative grid.
        let cfg = StormConfig {
            rows: 10,
            power: 3,
            saturating: true,
            task: Task::Classification,
            ..Default::default()
        };
        let mut rng = Xoshiro256::new(6);
        let mut device = StormModel::new(cfg, 4, 42);
        let mut leader = StormModel::new(cfg, 4, 42);
        let mut snap = device.snapshot();
        for epoch in 0..4u64 {
            device.insert_batch(&labelled_stream(&mut rng, 17, 3));
            let delta = device.delta_since(&snap, epoch);
            assert_eq!(delta.count, 17);
            assert_eq!(delta.cfg.task, Task::Classification);
            leader.apply_delta(&delta);
            snap = device.snapshot();
        }
        assert_eq!(leader.grid().counts_u32(), device.grid().counts_u32());
        assert_eq!(leader.count(), device.count());
    }

    #[test]
    fn state_swap_round_trips_counters_and_count() {
        use crate::config::CounterWidth;
        for task in [Task::Regression, Task::Classification] {
            for width in [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32] {
                let cfg = StormConfig {
                    rows: 6,
                    power: 3,
                    saturating: true,
                    counter_width: width,
                    task,
                    ..Default::default()
                };
                let mut rng = Xoshiro256::new(11);
                let mut src = StormModel::new(cfg, 4, 42);
                src.insert_batch(&labelled_stream(&mut rng, 40, 3));
                let mut arena = vec![0u8; src.bytes()];
                src.store_state(&mut arena);
                // A freshly built model paged in from the arena is
                // indistinguishable from the original: same counters,
                // count, and risk estimates.
                let mut dst = StormModel::new(cfg, 4, 42);
                dst.load_state(&arena, src.count());
                assert_eq!(dst.grid().counts_u32(), src.grid().counts_u32(), "{task:?} {width:?}");
                assert_eq!(dst.count(), src.count());
                let q = {
                    let mut t = gen_ball_point(&mut rng, 3, 0.5);
                    t.push(-1.0);
                    t
                };
                assert_eq!(
                    dst.estimate_risk_scaled(&q).to_bits(),
                    src.estimate_risk_scaled(&q).to_bits()
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "task mismatch")]
    fn cross_task_merge_panics() {
        let mut reg = StormModel::new(StormConfig::default(), 4, 1);
        let clf = StormModel::new(
            StormConfig { task: Task::Classification, ..Default::default() },
            4,
            1,
        );
        reg.merge_from(&clf);
    }

    #[test]
    #[should_panic]
    fn classification_delta_rejected_by_regression_sketch() {
        let cfg = StormConfig { task: Task::Classification, ..Default::default() };
        let clf = StormModel::new(cfg, 4, 1);
        let snap = clf.snapshot();
        let delta = clf.delta_since(&snap, 0);
        let mut reg = StormSketch::new(StormConfig::default(), 4, 1);
        reg.apply_delta(&delta);
    }
}
