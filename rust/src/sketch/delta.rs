//! Epoch-tagged sketch deltas — the unit of fleet synchronization.
//!
//! A device keeps ONE long-lived cumulative sketch and a snapshot of the
//! counters at the last sync barrier. At each sync round it emits a
//! [`SketchDelta`]: only the counter increments accumulated since the
//! snapshot, tagged with the round's epoch. Deltas are themselves
//! mergeable summaries (elementwise addition), so aggregators fold the
//! deltas of one epoch in place and forward a single merged delta
//! upstream; the leader applies each epoch's merged delta and ends up
//! with counters bit-identical to a one-shot merge of full sketches
//! (property-tested in `rust/tests/proptest_invariants.rs`).
//!
//! The wire representation (sparse varint runs, dense fallback) lives in
//! [`super::serialize`]; this module is the in-memory algebra.

use super::storm::{StormClassifierSketch, StormSketch};
use crate::config::{CounterWidth, StormConfig, Task};

/// Frozen device state at a sync barrier: counters + example count.
#[derive(Clone, Debug)]
pub struct SketchSnapshot {
    pub(crate) grid: super::counters::GridSnapshot,
    pub(crate) count: u64,
}

impl SketchSnapshot {
    /// Examples the sketch had absorbed when the snapshot was taken.
    pub fn count(&self) -> u64 {
        self.count
    }
}

/// Counter increments accumulated between two sync barriers, tagged with
/// the sync round (`epoch`) they belong to.
///
/// Increments are held as `u32` in memory regardless of the source
/// grid's width — every value is guaranteed to fit `width` (deltas are
/// exact differences of native-width counters), and `width` names the
/// narrowest wire representation the delta can ship at. Folding deltas
/// ([`Self::absorb`]) *widens* when sums outgrow the tag: a pool of u8
/// device rounds whose total crosses 255 re-ships as u16 — narrow-to-wide
/// aggregation is exact, saturation only ever happens device-local.
#[derive(Clone, Debug, PartialEq)]
pub struct SketchDelta {
    /// Sync round this delta belongs to.
    pub epoch: u64,
    /// Sketch geometry (must be merge-compatible fleet-wide; applying
    /// enforces it — see [`StormConfig::merge_compatible`]).
    pub cfg: StormConfig,
    /// Augmented example dimension (d + 1).
    pub dim: usize,
    /// Shared hash-family seed.
    pub seed: u64,
    /// Examples inserted within this delta.
    pub count: u64,
    /// Narrowest counter width holding every increment (wire width).
    pub width: CounterWidth,
    /// Dense row-major `R x B` counter increments (each `<= width.max_value()`).
    pub counts: Vec<u32>,
    /// True when the increments carry DP noise
    /// ([`super::privacy::noise_delta`]). Stamped on the v3 wire as a
    /// flag bit; folding a private delta into anything marks the result
    /// private (noise never washes out by merging).
    pub private: bool,
}

impl SketchDelta {
    /// An all-zero delta for the given geometry (identity of the merge).
    pub fn empty(epoch: u64, cfg: StormConfig, dim: usize, seed: u64) -> Self {
        SketchDelta {
            epoch,
            cfg,
            dim,
            seed,
            count: 0,
            width: cfg.counter_width,
            counts: vec![0; cfg.rows * cfg.buckets()],
            private: false,
        }
    }

    /// True when the delta carries no examples (and hence no increments).
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Number of cells with a nonzero increment.
    pub fn nonzero_cells(&self) -> usize {
        self.counts.iter().filter(|&&c| c != 0).count()
    }

    /// Fraction of cells touched — the wire encoder goes sparse below
    /// 50% (see `serialize::encode_delta`).
    pub fn populated_fraction(&self) -> f64 {
        if self.counts.is_empty() {
            return 0.0;
        }
        self.nonzero_cells() as f64 / self.counts.len() as f64
    }

    /// Sparse `(row-major cell index, increment)` view, indices ascending.
    pub fn sparse_cells(&self) -> Vec<(u32, u32)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c != 0)
            .map(|(i, &c)| (i as u32, c))
            .collect()
    }

    /// Fold another delta of the same epoch and geometry into this one
    /// (what aggregator nodes do per round). Uses the grid's saturation
    /// policy so an aggregated delta behaves exactly like the counters it
    /// will be applied to.
    pub fn merge_from(&mut self, other: &SketchDelta) {
        assert_eq!(self.epoch, other.epoch, "delta merge: epoch mismatch");
        self.absorb(other);
    }

    /// Fold a delta from *any* epoch into this one — the catch-up
    /// coalescing operation of the fault-tolerant protocol: a node whose
    /// upstream send was dropped pools the unshipped increments and
    /// re-ships them under a later round's tag (counter merging is
    /// epoch-agnostic addition; the epoch only names the round the bytes
    /// are attributed to). The result keeps the *newer* of the two
    /// epochs, so the re-shipped frame's `(from, epoch)` dedup key is
    /// one the receiver has never folded.
    pub fn absorb(&mut self, other: &SketchDelta) {
        assert!(self.cfg.merge_compatible(&other.cfg), "delta merge: config mismatch");
        assert_eq!(self.seed, other.seed, "delta merge: seed mismatch");
        assert_eq!(self.dim, other.dim, "delta merge: dim mismatch");
        assert_eq!(self.counts.len(), other.counts.len(), "delta merge: shape mismatch");
        self.epoch = self.epoch.max(other.epoch);
        let mut max_cell = 0u32;
        if self.cfg.saturating {
            for (c, o) in self.counts.iter_mut().zip(&other.counts) {
                *c = c.saturating_add(*o);
                max_cell = max_cell.max(*c);
            }
        } else {
            for (c, o) in self.counts.iter_mut().zip(&other.counts) {
                *c = c.wrapping_add(*o);
                max_cell = max_cell.max(*c);
            }
        }
        // Widening fold: never narrower than either operand, and wide
        // enough to carry every summed increment on the wire losslessly.
        self.width = self
            .width
            .max(other.width)
            .max(CounterWidth::fitting(max_cell));
        self.count += other.count;
        self.private |= other.private;
    }
}

/// Pool `delta` into `slot` (the unshipped-data accumulator used by
/// fault recovery): absorb across epochs, or occupy the empty slot.
pub fn pool_delta(slot: &mut Option<SketchDelta>, delta: SketchDelta) {
    match slot {
        Some(acc) => acc.absorb(&delta),
        None => *slot = Some(delta),
    }
}

/// Fold every delta in `batch` into `acc`, sharding the per-cell
/// addition across up to `workers` scoped threads by contiguous cell
/// range — the leader's round fold at million-device fan-in.
///
/// Per cell this is bit-identical to the sequential
/// [`SketchDelta::absorb`] chain: saturating (and wrapping) `u32`
/// addition is associative and commutative per cell, so any
/// shard/operand order yields the same value. The scalar fields fold
/// the way the chain does: the epoch keeps the max, the count sums,
/// and the width tag covers every operand width plus the fitted final
/// maximum cell (for saturating grids, cells grow monotonically, so
/// the final maximum equals the chain's running maximum and the width
/// tag matches the sequential chain exactly; a wrapping accumulator
/// could tag narrower than the chain, which is why this entry point is
/// reserved for folds that are applied locally and never re-encoded).
pub fn absorb_all_sharded(acc: &mut SketchDelta, batch: &[SketchDelta], workers: usize) {
    if batch.is_empty() {
        return;
    }
    for other in batch {
        assert!(acc.cfg.merge_compatible(&other.cfg), "delta fold: config mismatch");
        assert_eq!(acc.seed, other.seed, "delta fold: seed mismatch");
        assert_eq!(acc.dim, other.dim, "delta fold: dim mismatch");
        assert_eq!(acc.counts.len(), other.counts.len(), "delta fold: shape mismatch");
    }
    let cells = acc.counts.len();
    let saturating = acc.cfg.saturating;
    let shards = workers.max(1).min(cells.max(1));
    let chunk = cells.div_ceil(shards);
    let fold_range = |dst: &mut [u32], start: usize| -> u32 {
        for other in batch {
            let src = &other.counts[start..start + dst.len()];
            if saturating {
                for (c, o) in dst.iter_mut().zip(src) {
                    *c = c.saturating_add(*o);
                }
            } else {
                for (c, o) in dst.iter_mut().zip(src) {
                    *c = c.wrapping_add(*o);
                }
            }
        }
        dst.iter().copied().max().unwrap_or(0)
    };
    let max_cell = if shards <= 1 || cells == 0 {
        fold_range(&mut acc.counts, 0)
    } else {
        let mut shard_max = vec![0u32; acc.counts.chunks(chunk).count()];
        std::thread::scope(|s| {
            for ((ci, dst), mx) in
                acc.counts.chunks_mut(chunk).enumerate().zip(shard_max.iter_mut())
            {
                let fold_range = &fold_range;
                s.spawn(move || *mx = fold_range(dst, ci * chunk));
            }
        });
        shard_max.into_iter().max().unwrap_or(0)
    };
    for other in batch {
        acc.epoch = acc.epoch.max(other.epoch);
        acc.count += other.count;
        acc.width = acc.width.max(other.width);
        acc.private |= other.private;
    }
    acc.width = acc.width.max(CounterWidth::fitting(max_cell));
}

impl StormSketch {
    /// Freeze the current state for a later [`Self::delta_since`].
    pub fn snapshot(&self) -> SketchSnapshot {
        SketchSnapshot {
            grid: self.grid().snapshot(),
            count: self.count(),
        }
    }

    /// The increments accumulated since `snap`, tagged with `epoch`.
    /// Shipped at the device grid's native width — exact, since each
    /// increment is a difference of two native-width counter values.
    pub fn delta_since(&self, snap: &SketchSnapshot, epoch: u64) -> SketchDelta {
        SketchDelta {
            epoch,
            cfg: self.config(),
            dim: self.dim(),
            seed: self.seed(),
            count: self.count() - snap.count,
            width: self.config().counter_width,
            counts: self.grid().delta_since(&snap.grid),
            private: false,
        }
    }

    /// Apply a delta (merge of a remote device's round increments).
    /// Geometry, seed and dimension must match — the same compatibility
    /// contract as [`StormSketch::merge_from`]; widths may differ (a narrow
    /// device delta folds into a wide accumulator exactly — the widening
    /// merge of the fleet protocol).
    pub fn apply_delta(&mut self, delta: &SketchDelta) {
        assert!(
            self.config().merge_compatible(&delta.cfg),
            "apply_delta: config mismatch"
        );
        assert_eq!(self.seed(), delta.seed, "apply_delta: seed mismatch");
        assert_eq!(self.dim(), delta.dim, "apply_delta: dim mismatch");
        let (grid, count) = self.parts_mut();
        grid.apply_delta(&delta.counts);
        *count += delta.count;
    }

    /// Materialize a standalone sketch from a delta (used by the wire
    /// decoder's backward-compatible full-sketch entry point). Panics on
    /// a classification-tagged delta — those reassemble into
    /// [`StormClassifierSketch`] (via [`crate::sketch::model::StormModel`]).
    pub fn from_delta(delta: &SketchDelta) -> StormSketch {
        assert_eq!(delta.cfg.task, Task::Regression, "from_delta: classification frame");
        let mut sk = StormSketch::new(delta.cfg, delta.dim, delta.seed);
        sk.apply_delta(delta);
        sk
    }
}

/// The classifier sketch rides the same snapshot/delta algebra — this is
/// what lets labelled streams flow through the round-based fleet protocol
/// (and its fault-tolerant catch-up paths) unchanged.
impl StormClassifierSketch {
    /// Freeze the current state for a later [`Self::delta_since`].
    pub fn snapshot(&self) -> SketchSnapshot {
        SketchSnapshot {
            grid: self.grid().snapshot(),
            count: self.count(),
        }
    }

    /// The increments accumulated since `snap`, tagged with `epoch` and
    /// the classification task (the wire encoder stamps the task bit so
    /// a receiver can never fold these into a regression sketch). `dim`
    /// is the streamed example dimension `d + 1`, matching the
    /// regression convention.
    pub fn delta_since(&self, snap: &SketchSnapshot, epoch: u64) -> SketchDelta {
        SketchDelta {
            epoch,
            cfg: self.config(),
            dim: self.feature_dim() + 1,
            seed: self.seed(),
            count: self.count() - snap.count,
            width: self.config().counter_width,
            counts: self.grid().delta_since(&snap.grid),
            private: false,
        }
    }

    /// Apply a delta (merge of a remote device's round increments).
    /// Geometry, task, seed and dimension must match; widths may differ
    /// (narrow device deltas widen exactly).
    pub fn apply_delta(&mut self, delta: &SketchDelta) {
        assert!(
            self.config().merge_compatible(&delta.cfg),
            "apply_delta: config mismatch"
        );
        assert_eq!(self.seed(), delta.seed, "apply_delta: seed mismatch");
        assert_eq!(self.feature_dim() + 1, delta.dim, "apply_delta: dim mismatch");
        let (grid, count) = self.parts_mut();
        grid.apply_delta(&delta.counts);
        *count += delta.count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen_ball_point;
    use crate::util::rng::Xoshiro256;

    fn cfg() -> StormConfig {
        StormConfig { rows: 10, power: 3, saturating: true, ..Default::default() }
    }

    fn insert_n(sk: &mut StormSketch, rng: &mut Xoshiro256, n: usize) {
        for _ in 0..n {
            let z = gen_ball_point(rng, sk.dim(), 0.9);
            sk.insert(&z);
        }
    }

    #[test]
    fn rounds_of_deltas_reassemble_the_full_sketch() {
        let mut rng = Xoshiro256::new(5);
        let mut device = StormSketch::new(cfg(), 4, 42);
        let mut leader = StormSketch::new(cfg(), 4, 42);
        let mut snap = device.snapshot();
        for epoch in 0..4u64 {
            insert_n(&mut device, &mut rng, 17);
            let delta = device.delta_since(&snap, epoch);
            assert_eq!(delta.count, 17);
            leader.apply_delta(&delta);
            snap = device.snapshot();
        }
        assert_eq!(leader.grid().counts_u32(), device.grid().counts_u32());
        assert_eq!(leader.count(), device.count());
    }

    #[test]
    fn aggregator_fold_equals_leader_applying_each() {
        let mut rng = Xoshiro256::new(6);
        let mut a = StormSketch::new(cfg(), 3, 9);
        let mut b = StormSketch::new(cfg(), 3, 9);
        insert_n(&mut a, &mut rng, 12);
        insert_n(&mut b, &mut rng, 30);
        let da = a.delta_since(&StormSketch::new(cfg(), 3, 9).snapshot(), 2);
        let db = b.delta_since(&StormSketch::new(cfg(), 3, 9).snapshot(), 2);
        // Path 1: leader applies both.
        let mut leader1 = StormSketch::new(cfg(), 3, 9);
        leader1.apply_delta(&da);
        leader1.apply_delta(&db);
        // Path 2: aggregator folds, leader applies the merged delta.
        let mut folded = SketchDelta::empty(2, cfg(), 3, 9);
        folded.merge_from(&da);
        folded.merge_from(&db);
        let mut leader2 = StormSketch::new(cfg(), 3, 9);
        leader2.apply_delta(&folded);
        assert_eq!(leader1.grid().counts_u32(), leader2.grid().counts_u32());
        assert_eq!(leader1.count(), leader2.count());
        assert_eq!(folded.count, 42);
    }

    #[test]
    fn empty_delta_reports_empty_and_zero_population() {
        let d = SketchDelta::empty(0, cfg(), 3, 1);
        assert!(d.is_empty());
        assert_eq!(d.nonzero_cells(), 0);
        assert_eq!(d.populated_fraction(), 0.0);
        assert!(d.sparse_cells().is_empty());
    }

    #[test]
    fn sparse_cells_round_trip_dense() {
        let mut rng = Xoshiro256::new(7);
        let mut sk = StormSketch::new(cfg(), 3, 4);
        insert_n(&mut sk, &mut rng, 3);
        let delta = sk.delta_since(&StormSketch::new(cfg(), 3, 4).snapshot(), 1);
        let mut dense = vec![0u32; delta.counts.len()];
        for (i, c) in delta.sparse_cells() {
            dense[i as usize] = c;
        }
        assert_eq!(dense, delta.counts);
        // 3 inserts touch at most 2 cells per row out of 8 — sparse.
        assert!(delta.populated_fraction() < 0.5);
    }

    #[test]
    fn absorb_coalesces_across_epochs_keeping_newest() {
        let mut rng = Xoshiro256::new(8);
        let mut sk = StormSketch::new(cfg(), 3, 4);
        let base = sk.snapshot();
        insert_n(&mut sk, &mut rng, 9);
        let early = sk.delta_since(&base, 2);
        let snap = sk.snapshot();
        insert_n(&mut sk, &mut rng, 5);
        let late = sk.delta_since(&snap, 6);
        // Pooling the two partial deltas equals one delta over the whole
        // range, tagged with the newest epoch.
        let mut pooled: Option<SketchDelta> = None;
        pool_delta(&mut pooled, early);
        pool_delta(&mut pooled, late);
        let pooled = pooled.unwrap();
        let whole = sk.delta_since(&base, 6);
        assert_eq!(pooled, whole);
        assert_eq!(pooled.epoch, 6);
        assert_eq!(pooled.count, 14);
        // Absorbing an older epoch does not rewind the tag.
        let mut newer = sk.delta_since(&snap, 9);
        let older = SketchDelta::empty(1, cfg(), 3, 4);
        newer.absorb(&older);
        assert_eq!(newer.epoch, 9);
    }

    #[test]
    fn sharded_fold_matches_sequential_absorb_chain() {
        let mut rng = Xoshiro256::new(21);
        let make = |rng: &mut Xoshiro256, n: usize, epoch: u64| {
            let mut sk = StormSketch::new(cfg(), 3, 4);
            insert_n(&mut sk, rng, n);
            sk.delta_since(&StormSketch::new(cfg(), 3, 4).snapshot(), epoch)
        };
        let batch: Vec<SketchDelta> =
            (0..7).map(|i| make(&mut rng, 5 + i as usize, i)).collect();
        let mut sequential = SketchDelta::empty(0, cfg(), 3, 4);
        for d in &batch {
            sequential.absorb(d);
        }
        // Any shard count — including more shards than cells — yields
        // the identical delta, field for field.
        for workers in [1usize, 3, 8, 1000] {
            let mut sharded = SketchDelta::empty(0, cfg(), 3, 4);
            absorb_all_sharded(&mut sharded, &batch, workers);
            assert_eq!(sharded, sequential, "workers={workers}");
        }
        // Empty batch is a no-op.
        let mut acc = sequential.clone();
        absorb_all_sharded(&mut acc, &[], 4);
        assert_eq!(acc, sequential);
    }

    #[test]
    fn absorb_widens_when_sums_outgrow_the_tag() {
        // Two u8 device rounds whose pooled increments cross 255 re-ship
        // as u16 — the width tag always holds every value losslessly.
        let narrow_cfg = StormConfig {
            counter_width: crate::config::CounterWidth::U8,
            ..cfg()
        };
        let mut a = SketchDelta::empty(0, narrow_cfg, 3, 4);
        a.counts[0] = 200;
        a.count = 1;
        let mut b = SketchDelta::empty(1, narrow_cfg, 3, 4);
        b.counts[0] = 100;
        b.count = 1;
        assert_eq!(a.width, crate::config::CounterWidth::U8);
        a.absorb(&b);
        assert_eq!(a.counts[0], 300);
        assert_eq!(a.width, crate::config::CounterWidth::U16);
        // Width never narrows below an operand even when values are small.
        let mut wide = SketchDelta::empty(2, cfg(), 3, 4);
        wide.counts[1] = 1;
        let mut tiny = SketchDelta::empty(3, narrow_cfg, 3, 4);
        tiny.counts[1] = 1;
        wide.absorb(&tiny);
        assert_eq!(wide.width, crate::config::CounterWidth::U32);
    }

    #[test]
    fn private_flag_is_sticky_across_folds() {
        // Noise never washes out by merging: one private operand marks
        // every downstream fold private, on both fold paths.
        let mut a = SketchDelta::empty(0, cfg(), 3, 4);
        let mut b = SketchDelta::empty(0, cfg(), 3, 4);
        b.private = true;
        a.merge_from(&b);
        assert!(a.private);
        let mut acc = SketchDelta::empty(0, cfg(), 3, 4);
        let mut tagged = SketchDelta::empty(0, cfg(), 3, 4);
        tagged.private = true;
        absorb_all_sharded(&mut acc, &[SketchDelta::empty(0, cfg(), 3, 4), tagged], 4);
        assert!(acc.private);
        // And a clean fold stays clean.
        let mut clean = SketchDelta::empty(0, cfg(), 3, 4);
        clean.merge_from(&SketchDelta::empty(0, cfg(), 3, 4));
        assert!(!clean.private);
    }

    #[test]
    fn narrow_device_delta_folds_exactly_into_wide_leader() {
        // The widening-merge contract at the delta level: a u8 device's
        // rounds applied to a u32 leader reproduce the u32 run exactly.
        let narrow_cfg = StormConfig {
            counter_width: crate::config::CounterWidth::U8,
            ..cfg()
        };
        let mut rng = Xoshiro256::new(17);
        let mut device = StormSketch::new(narrow_cfg, 4, 42);
        let mut wide_ref = StormSketch::new(cfg(), 4, 42);
        let mut leader = StormSketch::new(cfg(), 4, 42);
        let mut snap = device.snapshot();
        for epoch in 0..3u64 {
            for _ in 0..9 {
                let z = gen_ball_point(&mut rng, 4, 0.9);
                device.insert(&z);
                wide_ref.insert(&z);
            }
            let delta = device.delta_since(&snap, epoch);
            assert_eq!(delta.width, crate::config::CounterWidth::U8);
            leader.apply_delta(&delta);
            snap = device.snapshot();
        }
        assert_eq!(leader.grid().counts_u32(), wide_ref.grid().counts_u32());
        assert_eq!(leader.count(), wide_ref.count());
    }

    #[test]
    #[should_panic(expected = "config mismatch")]
    fn apply_delta_cross_family_panics() {
        // A structured-family delta can never fold into a dense sketch:
        // the buckets were computed under different hyperplanes, so the
        // merge-compatibility gate (which compares hash families) fires.
        let mut sk = StormSketch::new(cfg(), 3, 1);
        let other = StormConfig {
            hash_family: crate::config::HashFamily::Sparse { density_permille: 100 },
            ..cfg()
        };
        let d = SketchDelta::empty(0, other, 3, 1);
        sk.apply_delta(&d);
    }

    #[test]
    #[should_panic]
    fn apply_delta_seed_mismatch_panics() {
        let mut sk = StormSketch::new(cfg(), 3, 1);
        let d = SketchDelta::empty(0, cfg(), 3, 2);
        sk.apply_delta(&d);
    }

    #[test]
    #[should_panic]
    fn delta_merge_epoch_mismatch_panics() {
        let mut a = SketchDelta::empty(0, cfg(), 3, 1);
        let b = SketchDelta::empty(1, cfg(), 3, 1);
        a.merge_from(&b);
    }
}
