//! Clarkson–Woodruff sketch-and-solve baseline (STOC'09): project the
//! `n x (d+1)` augmented system down to `s x (d+1)` with a count-sketch
//! matrix `S` (each row of X lands in one of `s` buckets with a random
//! sign, one pass, streaming-friendly) and solve the small least-squares
//! problem `min || S X theta - S y ||`.

use super::CompressedRegression;
use crate::data::dataset::Dataset;
use crate::linalg::matrix::Matrix;
use crate::linalg::solve::{lstsq, LstsqMethod};
use crate::util::rng::{Rng, Xoshiro256};

/// Apply a count-sketch projection `S X` with `s` output rows, one pass.
pub fn countsketch_project(x: &Matrix, s: usize, seed: u64) -> Matrix {
    assert!(s >= 1);
    let mut rng = Xoshiro256::new(seed);
    let mut out = Matrix::zeros(s, x.cols());
    for r in 0..x.rows() {
        let bucket = rng.below(s as u64) as usize;
        let sign = if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 };
        let row = x.row(r);
        let dst = out.row_mut(bucket);
        for c in 0..row.len() {
            dst[c] += sign * row[c];
        }
    }
    out
}

/// Count-sketch both X and y with the *same* S (same seed stream).
pub fn countsketch_system(x: &Matrix, y: &[f64], s: usize, seed: u64) -> (Matrix, Vec<f64>) {
    assert_eq!(x.rows(), y.len());
    let mut rng = Xoshiro256::new(seed);
    let mut sx = Matrix::zeros(s, x.cols());
    let mut sy = vec![0.0; s];
    for r in 0..x.rows() {
        let bucket = rng.below(s as u64) as usize;
        let sign = if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 };
        let row = x.row(r);
        let dst = sx.row_mut(bucket);
        for c in 0..row.len() {
            dst[c] += sign * row[c];
        }
        sy[bucket] += sign * y[r];
    }
    (sx, sy)
}

/// The baseline: sketch rows = budget / bytes-per-row (f32 storage, same
/// accounting as the sampling baselines).
pub struct ClarksonWoodruff;

impl CompressedRegression for ClarksonWoodruff {
    fn name(&self) -> &'static str {
        "cw-sketch"
    }

    fn fit(&self, ds: &Dataset, budget_bytes: usize, seed: u64) -> (Vec<f64>, usize) {
        let d = ds.dim();
        let s = super::rows_for_budget(budget_bytes, d).max(1);
        let (sx, sy) = countsketch_system(&ds.x, &ds.y, s, seed);
        let theta = lstsq(&sx, &sy, 0.0, LstsqMethod::NormalEquations);
        (theta, super::sample_bytes(s, d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::solve::mse;
    use crate::testing::assert_close;

    #[test]
    fn projection_preserves_column_sums_in_expectation() {
        // E[S X] column norms relate to X's: check the unbiasedness of
        // <Sx, Sy> for fixed vectors over many seeds.
        let mut rng = Xoshiro256::new(1);
        let x = Matrix::gaussian(40, 2, &mut rng);
        let col0 = x.col(0);
        let col1 = x.col(1);
        let exact: f64 = crate::util::mathx::dot(&col0, &col1);
        let trials = 3000;
        let mut acc = 0.0;
        for t in 0..trials {
            let sx = countsketch_project(&x, 12, t as u64);
            acc += crate::util::mathx::dot(&sx.col(0), &sx.col(1));
        }
        let emp = acc / trials as f64;
        let scale = exact.abs().max(1.0);
        assert_close(emp / scale, exact / scale, 0.1);
    }

    #[test]
    fn sketched_solve_approaches_exact_with_size() {
        let ds = synthetic::airfoil(9);
        let exact = crate::linalg::solve::lstsq(&ds.x, &ds.y, 0.0, LstsqMethod::Qr);
        let m_exact = mse(&ds.x, &ds.y, &exact);
        let cw = ClarksonWoodruff;
        let (theta, _) = cw.fit(&ds, super::super::sample_bytes(400, ds.dim()), 3);
        let m_cw = mse(&ds.x, &ds.y, &theta);
        // (1 + eps) approximation at s >> d.
        assert!(m_cw < m_exact * 1.5 + 1e-9, "cw mse {m_cw} vs exact {m_exact}");
    }

    #[test]
    fn fit_improves_with_budget() {
        let ds = synthetic::parkinsons(4);
        let cw = ClarksonWoodruff;
        let runs = 5;
        let avg = |rows: usize| -> f64 {
            (0..runs)
                .map(|s| {
                    let (t, _) = cw.fit(&ds, super::super::sample_bytes(rows, ds.dim()), s);
                    mse(&ds.x, &ds.y, &t).min(1e12)
                })
                .sum::<f64>()
                / runs as f64
        };
        assert!(avg(600) < avg(40), "no improvement with budget");
    }

    #[test]
    fn system_sketch_consistent_with_projection() {
        let mut rng = Xoshiro256::new(5);
        let x = Matrix::gaussian(30, 3, &mut rng);
        let y: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let (sx, sy) = countsketch_system(&x, &y, 8, 42);
        // Augment y as a 4th column and project with the same seed: the
        // first 3 columns must agree and the 4th must equal sy.
        let aug = Matrix::from_fn(30, 4, |r, c| if c < 3 { x[(r, c)] } else { y[r] });
        let s_aug = countsketch_project(&aug, 8, 42);
        for r in 0..8 {
            for c in 0..3 {
                assert_close(sx[(r, c)], s_aug[(r, c)], 1e-12);
            }
            assert_close(sy[r], s_aug[(r, 3)], 1e-12);
        }
    }
}
