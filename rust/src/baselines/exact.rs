//! Exact (uncompressed) least squares — the reference every compressed
//! method is measured against, and the "optimal theta under least-squares
//! ERM" STORM is shown to converge to.

use super::CompressedRegression;
use crate::data::dataset::Dataset;
use crate::linalg::solve::{lstsq, LstsqMethod};

/// Full-data least squares (ignores the budget; reports the true bytes of
/// the raw data, which is the honest memory cost of this "method").
pub struct ExactLeastSquares;

impl CompressedRegression for ExactLeastSquares {
    fn name(&self) -> &'static str {
        "exact-ls"
    }

    fn fit(&self, ds: &Dataset, _budget_bytes: usize, _seed: u64) -> (Vec<f64>, usize) {
        let theta = lstsq(&ds.x, &ds.y, 0.0, LstsqMethod::Qr);
        (theta, ds.raw_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::solve::mse;

    #[test]
    fn exact_ls_is_the_floor() {
        // No compressed method can beat exact LS on training MSE.
        let ds = synthetic::airfoil(11);
        let (theta, bytes) = ExactLeastSquares.fit(&ds, 0, 0);
        let m_exact = mse(&ds.x, &ds.y, &theta);
        assert_eq!(bytes, ds.raw_bytes());
        let rs = crate::baselines::random_sampling::RandomSampling;
        let (theta_rs, _) =
            crate::baselines::CompressedRegression::fit(&rs, &ds, 4096, 1);
        assert!(m_exact <= mse(&ds.x, &ds.y, &theta_rs) + 1e-12);
    }
}
