//! Random-sampling baseline: keep a uniform sample of the stream
//! (reservoir sampling — one pass, bounded memory, the honest streaming
//! counterpart of "random sampling" in the paper) and solve least squares
//! on the sample.
//!
//! This is the baseline that exhibits *sample-wise double descent*
//! (Nakkiran 2019): test/train risk peaks when the sample size crosses the
//! intrinsic dimension d. The Figure-4 harness sweeps straight through
//! that regime.

use super::CompressedRegression;
use crate::data::dataset::Dataset;
use crate::linalg::matrix::Matrix;
use crate::linalg::solve::{lstsq, LstsqMethod};
use crate::util::rng::{Rng, Xoshiro256};

/// Classic reservoir sampler over row indices.
pub struct Reservoir {
    k: usize,
    seen: u64,
    items: Vec<usize>,
    rng: Xoshiro256,
}

impl Reservoir {
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k > 0);
        Reservoir { k, seen: 0, items: Vec::with_capacity(k), rng: Xoshiro256::new(seed) }
    }

    /// Offer one item index.
    pub fn offer(&mut self, idx: usize) {
        self.seen += 1;
        if self.items.len() < self.k {
            self.items.push(idx);
        } else {
            let j = self.rng.below(self.seen);
            if (j as usize) < self.k {
                self.items[j as usize] = idx;
            }
        }
    }

    pub fn items(&self) -> &[usize] {
        &self.items
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }
}

/// The baseline.
pub struct RandomSampling;

impl CompressedRegression for RandomSampling {
    fn name(&self) -> &'static str {
        "random-sampling"
    }

    fn fit(&self, ds: &Dataset, budget_bytes: usize, seed: u64) -> (Vec<f64>, usize) {
        let d = ds.dim();
        let k = super::rows_for_budget(budget_bytes, d).max(1).min(ds.len());
        let mut res = Reservoir::new(k, seed);
        for i in 0..ds.len() {
            res.offer(i);
        }
        let idx = res.items();
        let xs = ds.x.select_rows(idx);
        let ys: Vec<f64> = idx.iter().map(|&i| ds.y[i]).collect();
        let theta = fit_sample(&xs, &ys);
        (theta, super::sample_bytes(idx.len(), d))
    }
}

/// Solve LS on a (possibly undersized) sample, ridge-stabilized only when
/// numerically necessary — intentionally NOT regularized enough to hide
/// double descent.
pub fn fit_sample(xs: &Matrix, ys: &[f64]) -> Vec<f64> {
    lstsq(xs, ys, 0.0, LstsqMethod::NormalEquations)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::solve::mse;

    #[test]
    fn reservoir_keeps_k_items() {
        let mut r = Reservoir::new(5, 1);
        for i in 0..100 {
            r.offer(i);
        }
        assert_eq!(r.items().len(), 5);
        assert_eq!(r.seen(), 100);
        assert!(r.items().iter().all(|&i| i < 100));
    }

    #[test]
    fn reservoir_is_approximately_uniform() {
        // Each of 20 items should appear in a k=5 reservoir with prob 1/4.
        let trials = 4000;
        let mut hits = vec![0usize; 20];
        for t in 0..trials {
            let mut r = Reservoir::new(5, t as u64);
            for i in 0..20 {
                r.offer(i);
            }
            for &i in r.items() {
                hits[i] += 1;
            }
        }
        for (i, &h) in hits.iter().enumerate() {
            let p = h as f64 / trials as f64;
            assert!((p - 0.25).abs() < 0.035, "item {i}: p={p}");
        }
    }

    #[test]
    fn fit_improves_with_budget() {
        let ds = synthetic::airfoil(5);
        let rs = RandomSampling;
        let (theta_small, b_small) = rs.fit(&ds, super::super::sample_bytes(12, ds.dim()), 3);
        let (theta_big, b_big) = rs.fit(&ds, super::super::sample_bytes(600, ds.dim()), 3);
        assert!(b_small < b_big);
        let m_small = mse(&ds.x, &ds.y, &theta_small);
        let m_big = mse(&ds.x, &ds.y, &theta_big);
        assert!(m_big < m_small, "big-sample mse {m_big} !< small-sample {m_small}");
    }

    #[test]
    fn budget_clamped_to_dataset() {
        let ds = synthetic::autos(1);
        let rs = RandomSampling;
        let (_, bytes) = rs.fit(&ds, usize::MAX / 2, 0);
        assert_eq!(bytes, super::super::sample_bytes(ds.len(), ds.dim()));
    }

    #[test]
    fn double_descent_peak_near_d() {
        // Average fit MSE over seeds at n ~ d should exceed MSE at both
        // n << d and n >> d (the Figure-4 phenomenon).
        let ds = synthetic::autos(7); // d = 26
        let rs = RandomSampling;
        let avg_mse = |rows: usize| -> f64 {
            let mut acc = 0.0;
            let runs = 12;
            for s in 0..runs {
                let (theta, _) = rs.fit(&ds, super::super::sample_bytes(rows, ds.dim()), s);
                acc += mse(&ds.x, &ds.y, &theta).min(1e9);
            }
            acc / runs as f64
        };
        let under = avg_mse(8);
        let at_d = avg_mse(26);
        let over = avg_mse(120);
        assert!(at_d > over, "peak {at_d} !> over {over}");
        assert!(at_d > under * 0.8, "peak {at_d} vs under {under}");
    }
}
