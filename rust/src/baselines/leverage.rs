//! Leverage-score sampling baseline.
//!
//! Rows are sampled with probability proportional to their statistical
//! leverage `l_i = ||Q_{i,:}||^2` (Q from the thin QR of X), then the LS
//! problem is solved on the reweighted sample — the classical adaptive
//! alternative to uniform sampling the paper compares against. Computing
//! exact scores requires a pass over the data (the paper notes online
//! approximations exist [8] but are "somewhat computationally expensive in
//! practice"); we provide the exact variant plus a cheaper sketched
//! approximation in the same spirit as online row sampling.

use super::CompressedRegression;
use crate::data::dataset::Dataset;
use crate::linalg::matrix::Matrix;
use crate::linalg::qr::thin_qr;
use crate::linalg::solve::{lstsq, LstsqMethod};
use crate::util::rng::{Rng, Xoshiro256};

/// Exact leverage-score sampling.
pub struct LeverageSampling;

/// Compute exact leverage scores of the dataset's design matrix.
pub fn exact_leverage_scores(x: &Matrix) -> Vec<f64> {
    thin_qr(x).leverage_scores()
}

/// Approximate leverage scores via a Clarkson–Woodruff projection of X to
/// `s` rows before the QR: O(nnz) sketch + small QR, the standard fast
/// approximation. Returns scores normalized to sum to d.
pub fn approximate_leverage_scores(x: &Matrix, s: usize, seed: u64) -> Vec<f64> {
    let d = x.cols();
    let s = s.max(d + 1).min(x.rows());
    // Sketch S X with a count-sketch matrix.
    let sx = crate::baselines::cw::countsketch_project(x, s, seed);
    // R from the sketched QR approximates the true R.
    let f = thin_qr(&sx);
    // Scores: || x_i R^{-1} ||^2.
    let mut scores = Vec::with_capacity(x.rows());
    for i in 0..x.rows() {
        let xi = x.row(i);
        // Solve R^T t = x_i (forward substitution on upper-tri transpose).
        let mut t = vec![0.0; d];
        for c in 0..d {
            let mut sum = xi[c];
            for k in 0..c {
                sum -= f.r[(k, c)] * t[k];
            }
            let rcc = f.r[(c, c)];
            t[c] = if rcc.abs() > 1e-300 { sum / rcc } else { 0.0 };
        }
        scores.push(t.iter().map(|v| v * v).sum());
    }
    // Normalize to sum to d (exact scores do).
    let total: f64 = scores.iter().sum();
    if total > 0.0 {
        let scale = d as f64 / total;
        for s in &mut scores {
            *s *= scale;
        }
    }
    scores
}

/// Sample `k` row indices with probability proportional to scores, with
/// replacement, returning (indices, importance weights 1/(k p_i)).
pub fn sample_by_scores(scores: &[f64], k: usize, seed: u64) -> (Vec<usize>, Vec<f64>) {
    let total: f64 = scores.iter().sum();
    assert!(total > 0.0, "degenerate scores");
    let probs: Vec<f64> = scores.iter().map(|s| s / total).collect();
    // Cumulative table + binary search.
    let mut cum = Vec::with_capacity(probs.len());
    let mut acc = 0.0;
    for p in &probs {
        acc += p;
        cum.push(acc);
    }
    let mut rng = Xoshiro256::new(seed);
    let mut idx = Vec::with_capacity(k);
    let mut weights = Vec::with_capacity(k);
    for _ in 0..k {
        let u = rng.uniform();
        let i = match cum.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cum.len() - 1),
        };
        idx.push(i);
        weights.push(1.0 / (k as f64 * probs[i]).max(1e-300));
    }
    (idx, weights)
}

impl CompressedRegression for LeverageSampling {
    fn name(&self) -> &'static str {
        "leverage-sampling"
    }

    fn fit(&self, ds: &Dataset, budget_bytes: usize, seed: u64) -> (Vec<f64>, usize) {
        let d = ds.dim();
        let k = super::rows_for_budget(budget_bytes, d).max(1).min(ds.len());
        let scores = exact_leverage_scores(&ds.x);
        let (idx, weights) = sample_by_scores(&scores, k, seed);
        // Importance-weighted LS: scale each sampled row by sqrt(w).
        let mut xs = ds.x.select_rows(&idx);
        let mut ys: Vec<f64> = idx.iter().map(|&i| ds.y[i]).collect();
        for (r, w) in weights.iter().enumerate() {
            let sw = w.sqrt();
            for v in xs.row_mut(r) {
                *v *= sw;
            }
            ys[r] *= sw;
        }
        let theta = lstsq(&xs, &ys, 0.0, LstsqMethod::NormalEquations);
        (theta, super::sample_bytes(k, d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::solve::mse;
    use crate::testing::assert_close;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn high_leverage_row_sampled_more() {
        // One far-outlying row dominates leverage.
        let mut rng = Xoshiro256::new(1);
        let mut x = Matrix::gaussian(50, 3, &mut rng);
        for v in x.row_mut(7) {
            *v *= 50.0;
        }
        let scores = exact_leverage_scores(&x);
        let max_i = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_i, 7);
        let (idx, _) = sample_by_scores(&scores, 200, 2);
        let hits7 = idx.iter().filter(|&&i| i == 7).count();
        assert!(hits7 > 30, "outlier sampled only {hits7}/200");
    }

    #[test]
    fn approximate_scores_track_exact() {
        let mut rng = Xoshiro256::new(3);
        let x = Matrix::gaussian(200, 5, &mut rng);
        let exact = exact_leverage_scores(&x);
        let approx = approximate_leverage_scores(&x, 60, 4);
        assert_close(approx.iter().sum::<f64>(), 5.0, 1e-6);
        // Rank correlation proxy: the top-20 exact rows should mostly be
        // in the top-60 approximate rows.
        let top = |s: &[f64], k: usize| -> std::collections::BTreeSet<usize> {
            let mut v: Vec<(usize, f64)> = s.iter().cloned().enumerate().collect();
            v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            v.into_iter().take(k).map(|(i, _)| i).collect()
        };
        let overlap = top(&exact, 20).intersection(&top(&approx, 60)).count();
        assert!(overlap >= 14, "overlap={overlap}");
    }

    #[test]
    fn fit_beats_tiny_random_on_leverage_heavy_data() {
        // Leverage sampling should at minimum produce a finite, sensible
        // model and improve with budget.
        let ds = synthetic::parkinsons(2);
        let lev = LeverageSampling;
        let (t_small, _) = lev.fit(&ds, super::super::sample_bytes(30, ds.dim()), 1);
        let (t_big, _) = lev.fit(&ds, super::super::sample_bytes(800, ds.dim()), 1);
        let m_small = mse(&ds.x, &ds.y, &t_small);
        let m_big = mse(&ds.x, &ds.y, &t_big);
        assert!(m_big < m_small, "{m_big} !< {m_small}");
    }

    #[test]
    fn weights_are_inverse_probability() {
        let scores = vec![1.0, 3.0];
        let (idx, w) = sample_by_scores(&scores, 100, 5);
        for (i, wi) in idx.iter().zip(&w) {
            let p = scores[*i] / 4.0;
            assert_close(*wi, 1.0 / (100.0 * p), 1e-9);
        }
    }
}
