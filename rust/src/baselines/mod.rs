//! Comparison baselines from the paper's evaluation (Figure 4):
//! random sampling, leverage-score sampling, and the Clarkson–Woodruff
//! linear-algebra sketch, plus the exact least-squares reference. Each
//! reports its memory footprint in bytes so the Figure-4 sweep can place
//! every method on a common memory axis.

pub mod random_sampling;
pub mod leverage;
pub mod cw;
pub mod exact;

use crate::data::dataset::Dataset;

/// A compressed-regression baseline: consumes a dataset under a memory
/// budget and produces a linear model.
pub trait CompressedRegression {
    /// Human-readable method name (figure legend).
    fn name(&self) -> &'static str;

    /// Fit under the given memory budget (bytes). Returns `theta`
    /// (length d) and the *actual* bytes used (methods quantize budgets
    /// to whole rows/columns).
    fn fit(&self, ds: &Dataset, budget_bytes: usize, seed: u64) -> (Vec<f64>, usize);
}

/// Bytes needed to store `rows` examples of dimension `d` in the smallest
/// standard dtype the paper allows (f32), plus the f32 target column.
pub fn sample_bytes(rows: usize, d: usize) -> usize {
    rows * (d + 1) * std::mem::size_of::<f32>()
}

/// Largest sample count that fits the budget.
pub fn rows_for_budget(budget_bytes: usize, d: usize) -> usize {
    budget_bytes / ((d + 1) * std::mem::size_of::<f32>())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_accounting_roundtrip() {
        let d = 9;
        for rows in [1usize, 7, 100] {
            let b = sample_bytes(rows, d);
            assert_eq!(rows_for_budget(b, d), rows);
        }
    }

    #[test]
    fn rows_for_budget_floors() {
        // 100 bytes, d=9 -> (9+1)*4 = 40 bytes/row -> 2 rows.
        assert_eq!(rows_for_budget(100, 9), 2);
        assert_eq!(rows_for_budget(39, 9), 0);
    }
}
