//! Figure-data export: every experiment writes its series as TSV (stdout
//! and/or files under `results/`) in a stable schema so figures can be
//! regenerated and diffed run-over-run.

use std::io::Write;
use std::path::Path;

/// A tabular series: named columns, row-major data.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "row width mismatch");
        self.rows.push(row);
    }

    /// Render as TSV with a `# title` header line.
    pub fn to_tsv(&self) -> String {
        let mut out = format!("# {}\n", self.title);
        out.push_str(&self.columns.join("\t"));
        out.push('\n');
        for row in &self.rows {
            let line: Vec<String> = row.iter().map(|v| format!("{v:.6e}")).collect();
            out.push_str(&line.join("\t"));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.to_tsv());
    }

    /// Write to a file, creating parent directories.
    pub fn write_file(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_tsv().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tsv_layout() {
        let mut t = Table::new("fig", &["x", "y"]);
        t.push(vec![1.0, 2.0]);
        let tsv = t.to_tsv();
        let lines: Vec<&str> = tsv.lines().collect();
        assert_eq!(lines[0], "# fig");
        assert_eq!(lines[1], "x\ty");
        assert!(lines[2].starts_with("1.0"));
    }

    #[test]
    fn file_roundtrip() {
        let mut t = Table::new("test", &["a"]);
        t.push(vec![3.5]);
        let p = std::env::temp_dir().join("storm_export_test/t.tsv");
        t.write_file(&p).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.contains("3.5"));
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push(vec![1.0]);
    }
}
