//! Metrics, traces and figure-data export.

pub mod histogram;
pub mod export;

use crate::linalg::matrix::Matrix;

/// Mean squared error of predictions vs targets.
pub fn mse_vec(pred: &[f64], y: &[f64]) -> f64 {
    assert_eq!(pred.len(), y.len());
    if y.is_empty() {
        return 0.0;
    }
    pred.iter().zip(y).map(|(p, t)| (p - t) * (p - t)).sum::<f64>() / y.len() as f64
}

/// MSE of a linear model on a design matrix.
pub fn model_mse(x: &Matrix, y: &[f64], theta: &[f64]) -> f64 {
    mse_vec(&x.matvec(theta), y)
}

/// Coefficient of determination R^2.
pub fn r_squared(x: &Matrix, y: &[f64], theta: &[f64]) -> f64 {
    let m = model_mse(x, y, theta);
    let var = crate::util::mathx::variance(y);
    if var == 0.0 {
        return if m == 0.0 { 1.0 } else { f64::NEG_INFINITY };
    }
    1.0 - m / var
}

/// Parameter-space distance `||theta - theta_ref|| / ||theta_ref||` — how
/// close a sketch-trained model is to the least-squares optimum (the
/// paper's convergence check).
pub fn relative_param_error(theta: &[f64], theta_ref: &[f64]) -> f64 {
    assert_eq!(theta.len(), theta_ref.len());
    let num: f64 = theta
        .iter()
        .zip(theta_ref)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let den = crate::util::mathx::norm2(theta_ref).max(1e-300);
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn mse_basics() {
        assert_close(mse_vec(&[1.0, 2.0], &[1.0, 4.0]), 2.0, 1e-12);
        assert_eq!(mse_vec(&[], &[]), 0.0);
    }

    #[test]
    fn r2_perfect_and_mean_model() {
        let x = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]);
        let y = vec![2.0, 4.0, 6.0];
        assert_close(r_squared(&x, &y, &[2.0]), 1.0, 1e-12);
        // Zero model leaves all the variance.
        assert!(r_squared(&x, &y, &[0.0]) < 0.0 + 1e-12);
    }

    #[test]
    fn relative_error_zero_for_identical() {
        assert_close(relative_param_error(&[1.0, 2.0], &[1.0, 2.0]), 0.0, 1e-12);
        assert_close(relative_param_error(&[2.0, 0.0], &[1.0, 0.0]), 1.0, 1e-12);
    }
}
