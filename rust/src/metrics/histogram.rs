//! Latency histogram with log-spaced buckets — the coordinator's request
//! telemetry (p50/p99 reporting without retaining every sample).

/// Log-bucketed histogram over microsecond latencies.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [base * ratio^i, base * ratio^{i+1})
    counts: Vec<u64>,
    base_us: f64,
    ratio: f64,
    total: u64,
    sum_us: f64,
    max_us: f64,
}

impl LatencyHistogram {
    /// 1us..~100s in 96 log buckets by default.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; 96],
            base_us: 1.0,
            ratio: 1.21,
            total: 0,
            sum_us: 0.0,
            max_us: 0.0,
        }
    }

    pub fn record_us(&mut self, us: f64) {
        let us = us.max(0.0);
        let idx = if us < self.base_us {
            0
        } else {
            ((us / self.base_us).ln() / self.ratio.ln()).floor() as usize
        };
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn record_secs(&mut self, secs: f64) {
        self.record_us(secs * 1e6);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Approximate percentile (bucket upper edge), q in [0, 100].
    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q / 100.0 * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base_us * self.ratio.powi(i as i32 + 1);
            }
        }
        self.max_us
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p99={:.1}us max={:.1}us",
            self.total,
            self.mean_us(),
            self.percentile_us(50.0),
            self.percentile_us(99.0),
            self.max_us
        )
    }

    /// Merge another histogram (same shape by construction).
    pub fn merge_from(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_bracket_samples() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile_us(50.0);
        assert!(p50 > 350.0 && p50 < 750.0, "p50={p50}");
        let p99 = h.percentile_us(99.0);
        assert!(p99 > 800.0, "p99={p99}");
        assert!(h.mean_us() > 400.0 && h.mean_us() < 600.0);
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(99.0), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn merge_combines_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(10.0);
        b.record_us(1000.0);
        a.merge_from(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 1000.0);
    }

    #[test]
    fn summary_is_stable_format() {
        let mut h = LatencyHistogram::new();
        h.record_us(5.0);
        let s = h.summary();
        assert!(s.contains("n=1") && s.contains("p99="));
    }
}
