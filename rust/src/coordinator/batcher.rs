//! Fixed-shape batching for the AOT insert path.
//!
//! The compiled insert executable has a static batch dimension; this
//! batcher accumulates streamed examples and emits full batches (plus a
//! final short batch), so the hot loop never recompiles. Padding rows are
//! masked inside the kernel — a padded example contributes exactly zero
//! counts, which the integration tests verify.

use crate::data::stream::Example;

/// Accumulates examples into fixed-size batches.
pub struct Batcher {
    capacity: usize,
    dim: usize,
    pending: Vec<Example>,
    emitted_batches: u64,
    emitted_examples: u64,
    finished: bool,
}

impl Batcher {
    pub fn new(capacity: usize, dim: usize) -> Self {
        assert!(capacity > 0 && dim > 0);
        Batcher {
            capacity,
            dim,
            pending: Vec::with_capacity(capacity),
            emitted_batches: 0,
            emitted_examples: 0,
            finished: false,
        }
    }

    /// Offer one example; returns a full batch when ready. Panics after
    /// [`Self::finish`] — a finished batcher must not silently swallow
    /// late examples.
    pub fn push(&mut self, example: Example) -> Option<Vec<Example>> {
        assert!(!self.finished, "Batcher::push after finish()");
        assert_eq!(example.len(), self.dim, "batcher dim mismatch");
        self.pending.push(example);
        if self.pending.len() >= self.capacity {
            self.emit()
        } else {
            None
        }
    }

    /// End-of-stream contract: emit the final short batch — exactly once,
    /// exactly the leftover examples (never padded here; the XLA insert
    /// kernel masks its own padding so padded rows contribute zero
    /// counts). Subsequent `finish` calls return `None`; subsequent
    /// `push` calls panic.
    pub fn finish(&mut self) -> Option<Vec<Example>> {
        if self.finished {
            return None;
        }
        self.finished = true;
        if self.pending.is_empty() {
            None
        } else {
            self.emit()
        }
    }

    /// Whether [`Self::finish`] has sealed this batcher.
    pub fn is_finished(&self) -> bool {
        self.finished
    }

    fn emit(&mut self) -> Option<Vec<Example>> {
        let batch = std::mem::take(&mut self.pending);
        self.emitted_batches += 1;
        self.emitted_examples += batch.len() as u64;
        Some(batch)
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn emitted_batches(&self) -> u64 {
        self.emitted_batches
    }

    pub fn emitted_examples(&self) -> u64 {
        self.emitted_examples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(v: f64) -> Example {
        vec![v, v]
    }

    #[test]
    fn emits_full_batches() {
        let mut b = Batcher::new(3, 2);
        assert!(b.push(ex(1.0)).is_none());
        assert!(b.push(ex(2.0)).is_none());
        let batch = b.push(ex(3.0)).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.emitted_batches(), 1);
    }

    #[test]
    fn finish_emits_final_short_batch_exactly_once() {
        let mut b = Batcher::new(4, 2);
        b.push(ex(1.0));
        b.push(ex(2.0));
        // The final short batch: exactly the leftovers, no padding rows.
        let batch = b.finish().unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch, vec![ex(1.0), ex(2.0)]);
        // Exactly once: a second finish is a no-op, counters are stable.
        assert!(b.finish().is_none());
        assert!(b.is_finished());
        assert_eq!(b.emitted_batches(), 1);
        assert_eq!(b.emitted_examples(), 2);
    }

    #[test]
    fn finish_on_batch_boundary_emits_nothing_extra() {
        let mut b = Batcher::new(2, 2);
        b.push(ex(1.0));
        let full = b.push(ex(2.0)).unwrap();
        assert_eq!(full.len(), 2);
        // Stream ended exactly on a boundary: no phantom empty batch.
        assert!(b.finish().is_none());
        assert_eq!(b.emitted_batches(), 1);
        assert_eq!(b.emitted_examples(), 2);
    }

    #[test]
    #[should_panic]
    fn push_after_finish_panics() {
        let mut b = Batcher::new(2, 2);
        b.push(ex(1.0));
        b.finish();
        b.push(ex(2.0));
    }

    #[test]
    #[should_panic]
    fn wrong_dim_rejected() {
        let mut b = Batcher::new(2, 3);
        b.push(vec![1.0]);
    }
}
