//! End-to-end training driver: the composition every example and the CLI
//! call into.
//!
//! Pipeline: load + unit-ball-scale the dataset -> partition streams over
//! the fleet -> run the fleet (devices sketch locally, deltas merge up the
//! topology) -> optionally warm-start via linear partition optimization ->
//! derivative-free training against the merged sketch (pure-rust or XLA
//! query backend) -> score against the exact least-squares reference.

use crate::config::RunConfig;
use crate::data::dataset::Dataset;
use crate::data::scale::scale_to_unit_ball_quantile;
use crate::data::stream::partition_streams;
use crate::edge::fleet::{run_fleet, FleetResult};
use crate::edge::topology::Topology;
use crate::linalg::solve::{lstsq, mse, LstsqMethod};
use crate::optim::dfo::DfoOptimizer;
use crate::optim::linopt::{linear_partition_init, LinOptConfig};
use crate::runtime::XlaStorm;
use crate::sketch::Sketch;
use anyhow::Result;

/// Which backend evaluates sketch queries during training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryBackend {
    /// Pure-rust scalar queries.
    Rust,
    /// AOT-compiled XLA executable (batched probes per DFO step).
    Xla,
}

/// Everything the driver measures.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub dataset: String,
    pub backend: QueryBackend,
    /// Model trained from the sketch alone.
    pub theta: Vec<f64>,
    /// Exact least-squares reference model on the same (scaled) data.
    pub theta_ls: Vec<f64>,
    /// Training MSE of the sketch model (scaled units).
    pub mse_storm: f64,
    /// Training MSE of the least-squares reference.
    pub mse_ls: f64,
    /// Relative parameter distance ||theta - theta_ls|| / ||theta_ls||.
    pub param_err: f64,
    pub sketch_bytes: usize,
    pub raw_bytes: usize,
    pub examples: u64,
    pub network_bytes: u64,
    pub fleet_wall_secs: f64,
    pub train_wall_secs: f64,
    /// DFO risk trace (iteration, estimated risk).
    pub trace: Vec<(usize, f64)>,
}

impl TrainReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: storm-mse={:.4e} ls-mse={:.4e} (ratio {:.2}) param-err={:.3} sketch={}B raw={}B net={}B",
            self.dataset,
            self.mse_storm,
            self.mse_ls,
            self.mse_storm / self.mse_ls.max(1e-300),
            self.param_err,
            self.sketch_bytes,
            self.raw_bytes,
            self.network_bytes,
        )
    }
}

/// Train STORM end-to-end on a dataset according to `cfg`.
///
/// `topology` shapes the fleet aggregation; `backend` selects the query
/// path. The XLA backend requires `cfg.artifacts_dir` with a compiled
/// artifact pair matching `(d+1, rows, power)`.
pub fn train(
    cfg: &RunConfig,
    mut ds: Dataset,
    topology: Topology,
    backend: QueryBackend,
) -> Result<TrainReport> {
    // 1. Scale into the unit ball (asymmetric-LSH requirement). Quantile
    //    scaling keeps typical norms informative — see data::scale docs.
    scale_to_unit_ball_quantile(&mut ds, crate::data::scale::DEFAULT_RADIUS, 0.9);
    let d = ds.dim();
    let raw_bytes = ds.raw_bytes();

    // 2. Fleet: devices sketch their shards, deltas merge to the leader.
    let family_seed = cfg.optimizer.seed ^ 0xA5A5_5A5A;
    let streams = partition_streams(&ds, cfg.fleet.devices, Some(cfg.fleet.seed));
    let FleetResult { sketch, network, wall_secs: fleet_wall_secs, examples, .. } =
        run_fleet(cfg.fleet, cfg.storm, topology, d + 1, family_seed, streams);

    // 3. Warm start from the partition structure, then DFO.
    let timer = crate::util::timer::Timer::start();
    let init = linear_partition_init(&sketch, LinOptConfig::default());
    let mut opt = DfoOptimizer::new(cfg.optimizer, d).with_init(&init);
    let mut trace: Vec<(usize, f64)> = Vec::new();
    let theta = match backend {
        QueryBackend::Rust => {
            // Each DFO iteration submits its whole candidate set (baseline
            // + antithetic probes) through RiskOracle::risk_batch, which
            // the sketch serves with the fused hash-bank query kernel —
            // zero per-candidate allocation (EXPERIMENTS.md §Perf).
            let t = opt.run(&sketch, cfg.optimizer.iters);
            trace = opt.trace().iter().map(|t| (t.iter, t.risk)).collect();
            t
        }
        QueryBackend::Xla => {
            let dir = cfg
                .artifacts_dir
                .clone()
                .unwrap_or_else(|| "artifacts".to_string());
            let exe = XlaStorm::load(&dir, d + 1, cfg.storm.rows, cfg.storm.power, sketch.hashes())?;
            let oracle = crate::coordinator::oracle::XlaRiskOracle::new(&exe, &sketch);
            // Same optimizer loop as the rust backend: each iteration's
            // candidate set goes through RiskOracle::risk_batch, which the
            // XLA oracle maps onto the K-wide compiled query entry point —
            // one PJRT execution per iteration, ~9x fewer than driving the
            // scalar oracle at queries = 8 (EXPERIMENTS.md §Perf).
            let t = opt.run(&oracle, cfg.optimizer.iters);
            trace = opt.trace().iter().map(|t| (t.iter, t.risk)).collect();
            if let Some(err) = oracle.last_error() {
                anyhow::bail!("XLA query path failed: {err}");
            }
            t
        }
    };
    let train_wall_secs = timer.elapsed_secs();

    // 4. Score against exact least squares on the same scaled data.
    let theta_ls = lstsq(&ds.x, &ds.y, 0.0, LstsqMethod::Qr);
    let mse_storm = mse(&ds.x, &ds.y, &theta);
    let mse_ls = mse(&ds.x, &ds.y, &theta_ls);
    let param_err = crate::metrics::relative_param_error(&theta, &theta_ls);

    Ok(TrainReport {
        dataset: ds.name.clone(),
        backend,
        theta,
        theta_ls,
        mse_storm,
        mse_ls,
        param_err,
        sketch_bytes: sketch.bytes(),
        raw_bytes,
        examples,
        network_bytes: network.bytes,
        fleet_wall_secs,
        train_wall_secs,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FleetConfig, OptimizerConfig, RunConfig, StormConfig};
    use crate::data::synthetic;

    fn quick_cfg() -> RunConfig {
        RunConfig {
            dataset: "synth2d-reg".to_string(),
            storm: StormConfig { rows: 400, power: 4, saturating: true },
            optimizer: OptimizerConfig {
                queries: 8,
                sigma: 0.3,
                step: 0.6,
                iters: 400,
                seed: 5,
            },
            fleet: FleetConfig {
                devices: 3,
                batch: 32,
                channel_capacity: 8,
                link_latency_us: 0,
                link_bandwidth_bps: 0,
                seed: 1,
            },
            artifacts_dir: None,
        }
    }

    #[test]
    fn end_to_end_training_beats_zero_model() {
        let ds = synthetic::synth2d_regression(600, 0.7, 0.0, 0.02, 3);
        let report = train(&quick_cfg(), ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        // The sketch-trained model must recover a large fraction of the
        // variance the LS model explains. The surrogate landscape is flat
        // near the optimum relative to sketch noise, so we assert a strong
        // variance reduction vs the zero model rather than LS-equality
        // (the Figure-4 harness measures the full comparison).
        assert!(report.mse_ls >= 0.0);
        let mut scaled = ds;
        crate::data::scale::scale_to_unit_ball_quantile(&mut scaled, 0.9, 0.9);
        let zero_mse = crate::linalg::solve::mse(&scaled.x, &scaled.y, &vec![0.0; 2]);
        // A single sketch draw carries family-level bias (the paper's own
        // protocol averages 10 independent sketches — the fig4 harness
        // does the same); a single run must still clearly learn.
        assert!(
            report.mse_storm < 0.8 * zero_mse,
            "storm mse {} vs zero-model {zero_mse} (ls {})",
            report.mse_storm,
            report.mse_ls
        );
        assert_eq!(report.examples, 600);
        assert!(report.network_bytes > 0);
        assert!(!report.trace.is_empty());
    }

    #[test]
    fn topologies_produce_identical_sketch_models() {
        // Same seeds + same merge algebra => identical trained models.
        let ds = synthetic::synth2d_regression(300, 0.5, 0.1, 0.02, 4);
        let cfg = quick_cfg();
        let a = train(&cfg, ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        let b = train(&cfg, ds, Topology::Tree { fanout: 2 }, QueryBackend::Rust).unwrap();
        assert_eq!(a.theta, b.theta);
    }

    #[test]
    fn xla_backend_without_artifacts_errors_cleanly() {
        let mut cfg = quick_cfg();
        cfg.artifacts_dir = Some("/nonexistent/artifacts".to_string());
        let ds = synthetic::synth2d_regression(50, 0.5, 0.0, 0.05, 5);
        let err = train(&cfg, ds, Topology::Star, QueryBackend::Xla);
        assert!(err.is_err());
    }

    #[test]
    fn summary_contains_key_numbers() {
        let ds = synthetic::synth2d_regression(200, 0.4, 0.0, 0.05, 6);
        let report = train(&quick_cfg(), ds, Topology::Star, QueryBackend::Rust).unwrap();
        let s = report.summary();
        assert!(s.contains("storm-mse=") && s.contains("sketch="));
    }
}
