//! End-to-end training driver: the composition every example and the CLI
//! call into.
//!
//! Pipeline: load + unit-ball-scale the dataset -> partition streams over
//! the fleet -> run `sync_rounds` rounds of delta synchronization
//! (devices sketch between barriers and ship epoch-tagged sparse deltas)
//! -> between rounds, DFO trains against the leader's *evolving* sketch
//! (pure-rust or XLA query backend) — the anytime model improves while
//! data is still streaming in -> score against the exact least-squares
//! reference. With `sync_rounds = 1` this degenerates to the classic
//! one-shot pipeline (sketch everything, then train once).

use crate::config::{RunConfig, StormConfig, Task};
use crate::data::dataset::Dataset;
use crate::data::scale::{scale_features_to_unit_ball, scale_to_unit_ball_quantile};
use crate::data::stream::partition_streams;
use crate::edge::fleet::run_fleet_model_with;
use crate::edge::topology::Topology;
use crate::linalg::solve::{lstsq, mse, LstsqMethod};
use crate::loss::margin::{accuracy, exact_margin_risk};
use crate::optim::dfo::DfoOptimizer;
use crate::optim::linopt::{linear_partition_init, LinOptConfig};
use crate::runtime::XlaStorm;
use crate::sketch::model::StormModel;
use crate::sketch::RiskSketch;
use anyhow::Result;

/// Which backend evaluates sketch queries during training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryBackend {
    /// Pure-rust scalar queries.
    Rust,
    /// AOT-compiled XLA executable (batched probes per DFO step).
    Xla,
}

/// One sync round as the coordinator saw it: what the model knew, what it
/// cost on the wire.
#[derive(Clone, Copy, Debug)]
pub struct RoundPoint {
    pub round: u64,
    /// Estimated surrogate risk at the end of the round's training slice
    /// (NaN if the round trained zero iterations).
    pub risk: f64,
    /// Cumulative examples in the leader sketch when the round closed.
    pub examples: u64,
    /// Fleet-wide network bytes attributed to the round.
    pub bytes: u64,
    /// Catch-up (retransmission) bytes within the round — nonzero only
    /// when faults made devices re-ship earlier rounds' increments.
    pub retransmit_bytes: u64,
    /// Cumulative per-device privacy budget spent when the round closed:
    /// `(round + 1) x epsilon_per_round` under basic sequential
    /// composition. Retransmitted frames re-ship the *same* noised bytes
    /// (the noise is seeded by `(family_seed, device, epoch)`), so
    /// catch-up traffic never spends extra budget. 0.0 when privacy is
    /// off.
    pub epsilon_spent: f64,
}

/// Everything the driver measures.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub dataset: String,
    pub backend: QueryBackend,
    /// The learning task the run trained (`[storm] task`).
    pub task: Task,
    /// Model trained from the sketch alone.
    pub theta: Vec<f64>,
    /// Exact reference model on the same (scaled) data: least squares for
    /// regression, the ridge linear probe for classification.
    pub theta_ls: Vec<f64>,
    /// Training loss of the sketch model (scaled units): MSE for
    /// regression, exact margin risk for classification.
    pub mse_storm: f64,
    /// Training loss of the reference model (same loss as `mse_storm`).
    pub mse_ls: f64,
    /// 0-1 training accuracy of the sketch model (classification only).
    pub accuracy: Option<f64>,
    /// Relative parameter distance ||theta - theta_ls|| / ||theta_ls||.
    pub param_err: f64,
    /// Leader (accumulator-tier) counter memory, width-true.
    pub sketch_bytes: usize,
    /// Per-device counter memory, width-true: when
    /// `[fleet] device_counter_width` narrows the device tier this is
    /// smaller than `sketch_bytes` by the width ratio.
    pub device_sketch_bytes: usize,
    pub raw_bytes: usize,
    pub examples: u64,
    pub network_bytes: u64,
    /// Total catch-up traffic across the run (0 on an ideal network).
    pub retransmit_bytes: u64,
    /// Fault events the chaos layer injected (0 on an ideal network).
    pub fault_events: u64,
    pub fleet_wall_secs: f64,
    pub train_wall_secs: f64,
    /// DFO risk trace (global iteration, estimated risk) across rounds.
    pub trace: Vec<(usize, f64)>,
    /// Per-sync-round risk/bytes trace (the communication-vs-rounds
    /// curve; see EXPERIMENTS.md §Communication vs. rounds).
    pub rounds: Vec<RoundPoint>,
    /// Total per-device epsilon the run spent — the epsilon ledger:
    /// `sync_rounds x epsilon_per_round` composed sequentially. Every
    /// device ships one noised delta per round against its own stream,
    /// so the per-device spend (not the sum over devices) is the
    /// meaningful privacy loss. 0.0 when `[privacy] epsilon_per_round`
    /// is unset.
    pub epsilon_total: f64,
}

impl TrainReport {
    /// One-line human summary. The regression format is unchanged from
    /// the seed; classification swaps the loss names and adds accuracy.
    pub fn summary(&self) -> String {
        let chaos = if self.fault_events > 0 {
            format!(" faults={} retransmit={}B", self.fault_events, self.retransmit_bytes)
        } else {
            String::new()
        };
        let privacy = if self.epsilon_total > 0.0 {
            format!(" epsilon={:.3}", self.epsilon_total)
        } else {
            String::new()
        };
        match self.task {
            Task::Regression => format!(
                "{}: storm-mse={:.4e} ls-mse={:.4e} (ratio {:.2}) param-err={:.3} sketch={}B device-sketch={}B raw={}B net={}B rounds={}{}{}",
                self.dataset,
                self.mse_storm,
                self.mse_ls,
                self.mse_storm / self.mse_ls.max(1e-300),
                self.param_err,
                self.sketch_bytes,
                self.device_sketch_bytes,
                self.raw_bytes,
                self.network_bytes,
                self.rounds.len().max(1),
                chaos,
                privacy,
            ),
            Task::Classification => format!(
                "{}: margin-risk={:.4e} probe-risk={:.4e} acc={:.1}% sketch={}B device-sketch={}B raw={}B net={}B rounds={}{}{}",
                self.dataset,
                self.mse_storm,
                self.mse_ls,
                self.accuracy.unwrap_or(0.0) * 100.0,
                self.sketch_bytes,
                self.device_sketch_bytes,
                self.raw_bytes,
                self.network_bytes,
                self.rounds.len().max(1),
                chaos,
                privacy,
            ),
        }
    }
}

/// Train STORM end-to-end on a dataset according to `cfg` — for either
/// task: `cfg.storm.task` selects the regression sketch or the margin
/// classifier, and everything below (fleet rounds, deltas, DFO between
/// barriers) is the same trait-driven pipeline over
/// [`StormModel`].
///
/// `topology` shapes the fleet aggregation; `backend` selects the query
/// path. The XLA backend requires `cfg.artifacts_dir` with a compiled
/// artifact pair matching `(d+1, rows, power)` and is regression-only.
pub fn train(
    cfg: &RunConfig,
    mut ds: Dataset,
    topology: Topology,
    backend: QueryBackend,
) -> Result<TrainReport> {
    let task = cfg.storm.task;
    anyhow::ensure!(
        !(task == Task::Classification && backend == QueryBackend::Xla),
        "the XLA query backend supports task = regression only"
    );
    anyhow::ensure!(
        cfg.storm.hash_family == crate::config::HashFamily::Dense
            || backend != QueryBackend::Xla,
        "the XLA query backend embeds dense Gaussian hyperplanes; hash_family = \"{}\" \
         requires the rust backend",
        cfg.storm.hash_family
    );
    // 1. Scale into the unit ball (asymmetric-LSH requirement).
    //    Regression scales the augmented [x, y] examples (quantile
    //    scaling keeps typical norms informative — see data::scale
    //    docs); classification scales features only, because ±1 labels
    //    fold into the hash sign and must stay exact.
    match task {
        Task::Regression => {
            scale_to_unit_ball_quantile(&mut ds, crate::data::scale::DEFAULT_RADIUS, 0.9);
        }
        Task::Classification => {
            scale_features_to_unit_ball(&mut ds, crate::data::scale::DEFAULT_RADIUS);
        }
    }
    let d = ds.dim();
    let raw_bytes = ds.raw_bytes();

    // 2 + 3. Fleet rounds with interleaved training. The iteration budget
    //    is split evenly across rounds, remainder to the *last* rounds so
    //    the most-informed sketch states always get trained.
    let rounds_n = cfg.fleet.sync_rounds.max(1);
    let base_iters = cfg.optimizer.iters / rounds_n;
    let extra = cfg.optimizer.iters % rounds_n;
    let family_seed = cfg.optimizer.seed ^ 0xA5A5_5A5A;
    let streams = partition_streams(&ds, cfg.fleet.devices, Some(cfg.fleet.seed));

    let timer = crate::util::timer::Timer::start();
    let mut opt: Option<DfoOptimizer> = None;
    let mut theta_opt: Option<Vec<f64>> = None;
    let mut round_risks: Vec<(u64, f64, u64)> = Vec::new();
    let mut xla_exe: Option<XlaStorm> = None;
    let mut xla_err: Option<anyhow::Error> = None;
    let mut train_secs = 0.0f64;

    let result = run_fleet_model_with::<StormModel, _>(
        cfg.fleet,
        cfg.storm,
        topology,
        d + 1,
        family_seed,
        streams,
        |round, sketch| {
            let t = crate::util::timer::Timer::start();
            let iters = base_iters + usize::from(round as usize >= rounds_n - extra);
            'train: {
                if iters == 0 || sketch.count() == 0 || xla_err.is_some() {
                    break 'train;
                }
                // Warm start once, from the first non-empty sketch state.
                // The partition perceptron reads PRP hyperplanes, so it
                // is regression-only; the classifier starts at zero.
                let opt = opt.get_or_insert_with(|| {
                    match sketch.as_regression() {
                        Some(reg) => {
                            let init = linear_partition_init(reg, LinOptConfig::default());
                            DfoOptimizer::new(cfg.optimizer, d).with_init(&init)
                        }
                        None => DfoOptimizer::new(cfg.optimizer, d),
                    }
                });
                let theta = match backend {
                    QueryBackend::Rust => {
                        // Each DFO iteration submits its whole candidate
                        // set through RiskOracle::risk_candidates — the
                        // rank-1 incremental query engine serves every
                        // probe in O(R * p) off the cached base
                        // projections, for BOTH tasks and all hash
                        // families (EXPERIMENTS.md §Perf; set
                        // STORM_QUERY_INCREMENTAL=off to fall back to
                        // the dense fused batch kernels).
                        let oracle = crate::optim::IncrementalOracle::new(sketch);
                        opt.run(&oracle, iters)
                    }
                    QueryBackend::Xla => {
                        // Gated to regression at entry.
                        let reg = sketch.as_regression().expect("xla backend is regression-only");
                        if xla_exe.is_none() {
                            let dir = cfg
                                .artifacts_dir
                                .clone()
                                .unwrap_or_else(|| "artifacts".to_string());
                            match XlaStorm::load(
                                &dir,
                                d + 1,
                                cfg.storm.rows,
                                cfg.storm.power,
                                reg.hashes(),
                            ) {
                                Ok(exe) => xla_exe = Some(exe),
                                Err(e) => {
                                    xla_err = Some(e);
                                    break 'train;
                                }
                            }
                        }
                        let exe = xla_exe.as_ref().expect("loaded xla executable");
                        // A fresh oracle per round snapshots the leader's
                        // evolving counters; the optimizer state persists.
                        let oracle = crate::coordinator::oracle::XlaRiskOracle::new(exe, reg);
                        let theta = opt.run(&oracle, iters);
                        if let Some(err) = oracle.last_error() {
                            xla_err = Some(anyhow::anyhow!("XLA query path failed: {err}"));
                            break 'train;
                        }
                        theta
                    }
                };
                theta_opt = Some(theta);
            }
            // For classification this is the per-round *margin-loss*
            // risk estimate — the anytime trace of Theorem 3 training.
            let risk = opt
                .as_ref()
                .and_then(|o| o.trace().last())
                .map_or(f64::NAN, |p| p.risk);
            round_risks.push((round, risk, sketch.count()));
            train_secs += t.elapsed_secs();
        },
    );
    if let Some(e) = xla_err {
        return Err(e);
    }
    let fleet_wall_secs = timer.elapsed_secs() - train_secs;
    let sketch = result.sketch;
    let theta = theta_opt.unwrap_or_else(|| vec![0.0; d]);
    let trace: Vec<(usize, f64)> = opt
        .as_ref()
        .map(|o| o.trace().iter().enumerate().map(|(i, p)| (i, p.risk)).collect())
        .unwrap_or_default();
    let rounds: Vec<RoundPoint> = round_risks
        .into_iter()
        .map(|(round, risk, examples)| RoundPoint {
            round,
            risk,
            examples,
            bytes: result.network.round_bytes(round),
            retransmit_bytes: result.network.round_retransmit_bytes(round),
            epsilon_spent: (round + 1) as f64 * cfg.fleet.epsilon_per_round,
        })
        .collect();
    // The epsilon ledger composes sequentially over the rounds that
    // actually closed: each round every device released one noised delta
    // of its own stream's increments. Retransmits replay identical bytes
    // (deterministic per-(device, epoch) noise), so they are not charged.
    let epsilon_total = rounds.last().map_or(0.0, |r| r.epsilon_spent);

    // 4. Score against an exact reference on the same scaled data:
    //    least squares + MSE for regression; for classification, the
    //    ridge linear probe and the exact margin risk of Theorem 3 (the
    //    loss the sketch actually estimates), plus 0-1 accuracy.
    let (theta_ls, mse_storm, mse_ls, param_err, acc) = match task {
        Task::Regression => {
            let theta_ls = lstsq(&ds.x, &ds.y, 0.0, LstsqMethod::Qr);
            let mse_storm = mse(&ds.x, &ds.y, &theta);
            let mse_ls = mse(&ds.x, &ds.y, &theta_ls);
            let param_err = crate::metrics::relative_param_error(&theta, &theta_ls);
            (theta_ls, mse_storm, mse_ls, param_err, None)
        }
        Task::Classification => {
            let xs: Vec<Vec<f64>> = (0..ds.len()).map(|i| ds.x.row(i).to_vec()).collect();
            let p = cfg.storm.power;
            let theta_ls = lstsq(&ds.x, &ds.y, 1e-6, LstsqMethod::NormalEquations);
            let risk_storm =
                if xs.is_empty() { 0.0 } else { exact_margin_risk(&theta, &xs, &ds.y, p) };
            let risk_probe =
                if xs.is_empty() { 0.0 } else { exact_margin_risk(&theta_ls, &xs, &ds.y, p) };
            // Only the hyperplane *direction* is identified — compare
            // unit-normalized parameters.
            let unit = |t: &[f64]| {
                let n = crate::util::mathx::norm2(t);
                if n > 0.0 { t.iter().map(|v| v / n).collect() } else { t.to_vec() }
            };
            let param_err =
                crate::metrics::relative_param_error(&unit(&theta), &unit(&theta_ls));
            let acc = if xs.is_empty() { 0.0 } else { accuracy(&theta, &xs, &ds.y) };
            (theta_ls, risk_storm, risk_probe, param_err, Some(acc))
        }
    };

    Ok(TrainReport {
        dataset: ds.name.clone(),
        backend,
        task,
        theta,
        theta_ls,
        mse_storm,
        mse_ls,
        accuracy: acc,
        param_err,
        sketch_bytes: sketch.bytes(),
        device_sketch_bytes: result
            .devices
            .iter()
            .map(|d| d.sketch_bytes)
            .max()
            .unwrap_or_else(|| {
                StormConfig {
                    counter_width: cfg
                        .fleet
                        .device_counter_width
                        .unwrap_or(cfg.storm.counter_width),
                    ..cfg.storm
                }
                .sketch_bytes()
            }),
        raw_bytes,
        examples: result.examples,
        network_bytes: result.network.bytes,
        retransmit_bytes: result.network.retransmit_bytes(),
        fault_events: result.faults.total(),
        fleet_wall_secs,
        train_wall_secs: train_secs,
        trace,
        rounds,
        epsilon_total,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FleetConfig, OptimizerConfig, RunConfig, StormConfig};
    use crate::data::synthetic;

    fn quick_cfg() -> RunConfig {
        RunConfig {
            dataset: "synth2d-reg".to_string(),
            storm: StormConfig { rows: 400, power: 4, saturating: true, ..Default::default() },
            optimizer: OptimizerConfig {
                queries: 8,
                sigma: 0.3,
                step: 0.6,
                iters: 400,
                seed: 5,
            },
            fleet: FleetConfig {
                devices: 3,
                batch: 32,
                channel_capacity: 8,
                link_latency_us: 0,
                link_bandwidth_bps: 0,
                sync_rounds: 1,
                min_quorum: 0,
                faults_seed: None,
                device_counter_width: None,
                workers: 0,
                fan_in: 2,
                epsilon_per_round: 0.0,
                decay_keep_permille: 1000,
                seed: 1,
            },
            artifacts_dir: None,
        }
    }

    #[test]
    fn end_to_end_training_beats_zero_model() {
        let ds = synthetic::synth2d_regression(600, 0.7, 0.0, 0.02, 3);
        let report = train(&quick_cfg(), ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        // The sketch-trained model must recover a large fraction of the
        // variance the LS model explains. The surrogate landscape is flat
        // near the optimum relative to sketch noise, so we assert a strong
        // variance reduction vs the zero model rather than LS-equality
        // (the Figure-4 harness measures the full comparison).
        assert!(report.mse_ls >= 0.0);
        let mut scaled = ds;
        crate::data::scale::scale_to_unit_ball_quantile(&mut scaled, 0.9, 0.9);
        let zero_mse = crate::linalg::solve::mse(&scaled.x, &scaled.y, &vec![0.0; 2]);
        // A single sketch draw carries family-level bias (the paper's own
        // protocol averages 10 independent sketches — the fig4 harness
        // does the same); a single run must still clearly learn.
        assert!(
            report.mse_storm < 0.8 * zero_mse,
            "storm mse {} vs zero-model {zero_mse} (ls {})",
            report.mse_storm,
            report.mse_ls
        );
        assert_eq!(report.examples, 600);
        assert!(report.network_bytes > 0);
        assert!(!report.trace.is_empty());
        assert_eq!(report.rounds.len(), 1);
    }

    #[test]
    fn topologies_produce_identical_sketch_models() {
        // Same seeds + same merge algebra => identical trained models.
        let ds = synthetic::synth2d_regression(300, 0.5, 0.1, 0.02, 4);
        let cfg = quick_cfg();
        let a = train(&cfg, ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        let b = train(&cfg, ds, Topology::Tree { fanout: 2 }, QueryBackend::Rust).unwrap();
        assert_eq!(a.theta, b.theta);
    }

    #[test]
    fn round_based_training_is_online_and_topology_invariant() {
        // With R sync rounds, training interleaves with ingestion; the
        // per-round sketch states (and therefore the final model) are
        // identical across aggregation topologies.
        let ds = synthetic::synth2d_regression(300, 0.5, 0.1, 0.02, 4);
        let mut cfg = quick_cfg();
        cfg.fleet.sync_rounds = 4;
        let a = train(&cfg, ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        let b = train(&cfg, ds.clone(), Topology::Tree { fanout: 2 }, QueryBackend::Rust).unwrap();
        assert_eq!(a.theta, b.theta);
        assert_eq!(a.rounds.len(), 4);
        // The anytime trace: examples grow monotonically to the dataset
        // size, every trained round has a finite risk, and the full DFO
        // budget was spent across the rounds.
        let ex: Vec<u64> = a.rounds.iter().map(|r| r.examples).collect();
        assert!(ex.windows(2).all(|w| w[0] <= w[1]), "{ex:?}");
        assert_eq!(*ex.last().unwrap(), 300);
        assert!(a.rounds.iter().all(|r| r.risk.is_finite()), "{:?}", a.rounds);
        assert_eq!(a.trace.len(), cfg.optimizer.iters);
        // Bytes are attributed per round and sum below the total (Done
        // frames carry no epoch).
        let round_bytes: u64 = a.rounds.iter().map(|r| r.bytes).sum();
        assert!(round_bytes > 0 && round_bytes <= a.network_bytes);
        // Determinism across repeat runs.
        let c = train(&cfg, ds, Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(a.theta, c.theta);
    }

    #[test]
    fn single_round_matches_seed_one_shot_behaviour() {
        // sync_rounds = 1 must reproduce the classic pipeline exactly:
        // the whole iteration budget runs against the fully-merged sketch.
        let ds = synthetic::synth2d_regression(200, 0.4, 0.0, 0.05, 6);
        let cfg = quick_cfg();
        let report = train(&cfg, ds, Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(report.rounds.len(), 1);
        assert_eq!(report.rounds[0].examples, 200);
        assert_eq!(report.trace.len(), cfg.optimizer.iters);
    }

    #[test]
    fn chaos_training_completes_with_monotone_anytime_trace() {
        // Under a seeded fault schedule the run must still complete,
        // ingest everything, keep the per-round examples trace monotone,
        // and account its catch-up traffic. (The FINAL counters are
        // fault-invariant — property-tested in proptest_invariants —
        // but per-round sketch states may shift, so theta is compared
        // for determinism, not against the fault-free run.)
        let ds = synthetic::synth2d_regression(300, 0.5, 0.1, 0.02, 4);
        let mut cfg = quick_cfg();
        cfg.fleet.sync_rounds = 5;
        cfg.fleet.devices = 4;
        cfg.fleet.faults_seed = Some(0xBAD);
        let a = train(&cfg, ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(a.examples, 300);
        assert_eq!(a.rounds.len(), 5, "every round must close under faults");
        assert!(a.fault_events > 0, "chaos was vacuous");
        let ex: Vec<u64> = a.rounds.iter().map(|r| r.examples).collect();
        assert!(ex.windows(2).all(|w| w[0] <= w[1]), "monotone examples trace: {ex:?}");
        // The trace may close its last round before the final catch-up
        // frame lands (the leader folds it before returning — the final
        // COUNTERS are complete, property-tested elsewhere).
        assert!(*ex.last().unwrap() <= 300, "{ex:?}");
        // Retransmit bytes are accounted per round and bounded by the
        // round's total bytes.
        for r in &a.rounds {
            assert!(r.retransmit_bytes <= r.bytes, "{r:?}");
        }
        assert!(a.summary().contains("faults="));
    }

    #[test]
    fn private_training_reports_a_composed_epsilon_ledger() {
        // Privacy on: the report carries the sequentially-composed
        // per-device budget — exactly rounds x epsilon_per_round — the
        // per-round ledger grows linearly, and the summary surfaces it.
        let ds = synthetic::synth2d_regression(300, 0.5, 0.1, 0.02, 4);
        let mut cfg = quick_cfg();
        cfg.fleet.sync_rounds = 4;
        cfg.fleet.epsilon_per_round = 0.75;
        let a = train(&cfg, ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(a.rounds.len(), 4);
        assert_eq!(a.epsilon_total, 4.0 * 0.75);
        for (i, r) in a.rounds.iter().enumerate() {
            assert_eq!(r.epsilon_spent, (i + 1) as f64 * 0.75, "{r:?}");
        }
        assert!(a.summary().contains("epsilon=3.000"), "{}", a.summary());
        // Example accounting stays exact: only counter cells are noised.
        assert_eq!(a.examples, 300);
        // Deterministic noise seeds => deterministic private training.
        let b = train(&cfg, ds, Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(a.theta, b.theta);
    }

    #[test]
    fn privacy_off_reports_a_zero_ledger_and_no_summary_field() {
        let ds = synthetic::synth2d_regression(200, 0.4, 0.0, 0.05, 6);
        let report = train(&quick_cfg(), ds, Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(report.epsilon_total, 0.0);
        assert!(report.rounds.iter().all(|r| r.epsilon_spent == 0.0));
        assert!(!report.summary().contains("epsilon="), "{}", report.summary());
    }

    #[test]
    fn decayed_training_still_learns_and_stays_deterministic() {
        // Leader-side decay changes the sketch (old rounds fade) but the
        // pipeline must still train a clearly-better-than-zero model and
        // reproduce itself run to run.
        let ds = synthetic::synth2d_regression(600, 0.7, 0.0, 0.02, 3);
        let mut cfg = quick_cfg();
        cfg.fleet.sync_rounds = 3;
        cfg.fleet.decay_keep_permille = 800;
        let a = train(&cfg, ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(a.examples, 600, "device-side accounting is decay-free");
        assert!(a.mse_storm.is_finite());
        let b = train(&cfg, ds, Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(a.theta, b.theta);
    }

    #[test]
    fn xla_backend_without_artifacts_errors_cleanly() {
        let mut cfg = quick_cfg();
        cfg.artifacts_dir = Some("/nonexistent/artifacts".to_string());
        let ds = synthetic::synth2d_regression(50, 0.5, 0.0, 0.05, 5);
        let err = train(&cfg, ds, Topology::Star, QueryBackend::Xla);
        assert!(err.is_err());
    }

    #[test]
    fn summary_contains_key_numbers() {
        let ds = synthetic::synth2d_regression(200, 0.4, 0.0, 0.05, 6);
        let report = train(&quick_cfg(), ds, Topology::Star, QueryBackend::Rust).unwrap();
        let s = report.summary();
        assert!(s.contains("storm-mse=") && s.contains("sketch=") && s.contains("rounds="));
        assert!(s.contains("device-sketch="));
        assert_eq!(report.device_sketch_bytes, report.sketch_bytes, "same tier width by default");
    }

    fn quick_clf_cfg() -> RunConfig {
        let mut cfg = quick_cfg();
        cfg.dataset = "synth2d-clf".to_string();
        cfg.storm.task = Task::Classification;
        // Margin-risk estimates are noisier per row than the paired PRP
        // surrogate; more rows + the convex p = 2 margin loss keep the
        // DFO landscape informative.
        cfg.storm.rows = 600;
        cfg.storm.power = 2;
        cfg.optimizer.iters = 400;
        cfg
    }

    #[test]
    fn classification_trains_end_to_end_through_the_fleet() {
        let ds = synthetic::synth2d_classification(1500, 0.8, 0.2, 13);
        let report = train(&quick_clf_cfg(), ds, Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(report.task, Task::Classification);
        assert_eq!(report.examples, 1500);
        let acc = report.accuracy.expect("classification reports accuracy");
        // Well-separated blobs: the sketch-trained hyperplane must
        // clearly classify (the zero model scores 0, chance is ~0.5).
        assert!(acc > 0.7, "accuracy {acc}");
        // The exact margin risk of the trained model beats the
        // uninformative zero direction (whose risk is exactly 1.0).
        assert!(report.mse_storm < 0.9, "margin risk {}", report.mse_storm);
        assert!(report.summary().contains("margin-risk=") && report.summary().contains("acc="));
        assert!(!report.trace.is_empty());
        assert!(report.network_bytes > 0);
    }

    #[test]
    fn classification_trains_under_faults_with_identical_final_counters() {
        // End-to-end acceptance: a chaotic classification fleet completes,
        // learns, and (determinism) reproduces itself run-to-run.
        let ds = synthetic::synth2d_classification(1500, 0.8, 0.2, 13);
        let mut cfg = quick_clf_cfg();
        cfg.fleet.sync_rounds = 4;
        cfg.fleet.devices = 4;
        cfg.fleet.faults_seed = Some(0xC1A5);
        let a = train(&cfg, ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(a.examples, 1500);
        assert_eq!(a.rounds.len(), 4, "every round must close under faults");
        assert!(a.fault_events > 0, "chaos was vacuous");
        // Per-round margin-loss risks are recorded for trained rounds.
        assert!(a.rounds.iter().any(|r| r.risk.is_finite()), "{:?}", a.rounds);
        let b = train(&cfg, ds, Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(a.theta, b.theta, "chaotic training is deterministic per seed");
    }

    #[test]
    fn classification_topologies_produce_identical_models() {
        let ds = synthetic::synth2d_classification(600, 0.8, 0.2, 5);
        let mut cfg = quick_clf_cfg();
        cfg.optimizer.iters = 60;
        let a = train(&cfg, ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        let b = train(&cfg, ds, Topology::Chain, QueryBackend::Rust).unwrap();
        assert_eq!(a.theta, b.theta);
    }

    #[test]
    fn classification_rejects_the_xla_backend() {
        let ds = synthetic::synth2d_classification(100, 0.8, 0.2, 5);
        let err = train(&quick_clf_cfg(), ds, Topology::Star, QueryBackend::Xla);
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("regression only"));
    }

    #[test]
    fn narrow_device_tier_trains_identically_and_reports_width_true_bytes() {
        // 200 examples over 3 devices never push a u8 device cell near
        // saturation, so the narrow-tier run trains the *same* model as
        // the all-u32 run while reporting a quarter of the device memory.
        let ds = synthetic::synth2d_regression(200, 0.4, 0.0, 0.05, 6);
        let cfg = quick_cfg();
        let wide = train(&cfg, ds.clone(), Topology::Star, QueryBackend::Rust).unwrap();
        let mut narrow_cfg = cfg;
        narrow_cfg.fleet.device_counter_width = Some(crate::config::CounterWidth::U8);
        let narrow = train(&narrow_cfg, ds, Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(narrow.theta, wide.theta, "widening merge must not move the model");
        assert_eq!(narrow.sketch_bytes, 400 * 16 * 4, "leader stays u32");
        assert_eq!(narrow.device_sketch_bytes, 400 * 16, "u8 devices: 1 byte/cell");
        assert_eq!(wide.device_sketch_bytes, wide.sketch_bytes);
    }
}
