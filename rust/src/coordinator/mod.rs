//! The leader/coordinator: glues the fleet, the sketch, the optimizer and
//! the XLA runtime into the end-to-end training system.
//!
//! * [`batcher`] — fixed-shape batching (pad + mask) for the AOT insert
//!   path, whose compiled batch size is static;
//! * [`oracle`] — [`crate::optim::RiskOracle`] implementations backed by
//!   the XLA query executable (batched DFO probes in one call);
//! * [`driver`] — the end-to-end train loop: stream -> fleet -> merged
//!   sketch -> (linopt init) -> DFO -> report;
//! * [`state`] — training state checkpointing.

pub mod batcher;
pub mod ingest;
pub mod oracle;
pub mod driver;
pub mod state;
