//! Leader-side bulk ingest through the XLA insert artifact.
//!
//! Edge devices sketch with the scalar path (they are simulated MCUs),
//! but the *leader* may also receive raw streams directly — e.g. local
//! sensors, or replaying an archive into a fresh sketch configuration.
//! This path batches examples ([`super::batcher::Batcher`]) and runs the
//! AOT-compiled Pallas insert kernel, merging each `[R, 2^p]` histogram
//! delta into the live sketch. Counters are bit-identical to scalar
//! inserts (shared hyperplanes; asserted by `integration_runtime`).

use super::batcher::Batcher;
use crate::data::stream::StreamSource;
use crate::runtime::XlaStorm;
use crate::sketch::storm::StormSketch;
use anyhow::Result;

/// Ingest statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestReport {
    pub examples: u64,
    pub batches: u64,
    pub executions: u64,
    pub wall_secs: f64,
}

/// Drain `stream` into `sketch` through the XLA insert executable.
pub fn xla_bulk_ingest(
    stream: &mut dyn StreamSource,
    exe: &XlaStorm,
    sketch: &mut StormSketch,
) -> Result<IngestReport> {
    let timer = crate::util::timer::Timer::start();
    let mut batcher = Batcher::new(exe.batch_size(), StormSketch::dim(sketch));
    let mut report = IngestReport::default();
    let mut submit = |batch: Vec<crate::data::stream::Example>,
                      report: &mut IngestReport|
     -> Result<()> {
        let n = batch.len() as u64;
        let delta = exe.insert_counts(&batch)?;
        sketch.add_batch_counts(&delta, n);
        report.examples += n;
        report.batches += 1;
        report.executions += 1;
        Ok(())
    };
    while let Some(example) = stream.next_example() {
        if let Some(batch) = batcher.push(example) {
            submit(batch, &mut report)?;
        }
    }
    if let Some(batch) = batcher.flush() {
        submit(batch, &mut report)?;
    }
    report.wall_secs = timer.elapsed_secs();
    Ok(report)
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end (vs the scalar path, bit-for-bit) in
    // rust/tests/integration_runtime.rs::bulk_ingest_matches_scalar_path;
    // unit-level batching behaviour is covered in batcher.rs.
}
