//! Leader-side bulk ingest through the XLA insert artifact.
//!
//! Edge devices sketch with the scalar path (they are simulated MCUs),
//! but the *leader* may also receive raw streams directly — e.g. local
//! sensors, or replaying an archive into a fresh sketch configuration.
//! This path batches examples ([`super::batcher::Batcher`]) and runs the
//! AOT-compiled Pallas insert kernel, merging each `[R, 2^p]` histogram
//! delta into the live sketch. Counters are bit-identical to scalar
//! inserts (shared hyperplanes; asserted by `integration_runtime`).
//!
//! [`rust_bulk_ingest`] is the artifact-free sibling: same batching, but
//! the batches go through the fused hash-bank kernel
//! ([`StormSketch::insert_batch`]) instead of PJRT — the fast pure-rust
//! leader ingest when no compiled artifacts are available.

use super::batcher::Batcher;
use crate::data::stream::StreamSource;
use crate::runtime::XlaStorm;
use crate::sketch::storm::StormSketch;
use anyhow::Result;

/// Ingest statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestReport {
    pub examples: u64,
    pub batches: u64,
    pub executions: u64,
    pub wall_secs: f64,
}

/// Drain `stream` into `sketch` through the XLA insert executable.
pub fn xla_bulk_ingest(
    stream: &mut dyn StreamSource,
    exe: &XlaStorm,
    sketch: &mut StormSketch,
) -> Result<IngestReport> {
    let timer = crate::util::timer::Timer::start();
    let mut batcher = Batcher::new(exe.batch_size(), StormSketch::dim(sketch));
    let mut report = IngestReport::default();
    let mut submit = |batch: Vec<crate::data::stream::Example>,
                      report: &mut IngestReport|
     -> Result<()> {
        let n = batch.len() as u64;
        let delta = exe.insert_counts(&batch)?;
        sketch.add_batch_counts(&delta, n);
        report.examples += n;
        report.batches += 1;
        report.executions += 1;
        Ok(())
    };
    while let Some(example) = stream.next_example() {
        if let Some(batch) = batcher.push(example) {
            submit(batch, &mut report)?;
        }
    }
    // End-of-stream: finish() seals the batcher and emits the final short
    // batch exactly once (the compiled kernel masks its padding rows, so
    // the short batch contributes exactly its own counts).
    if let Some(batch) = batcher.finish() {
        submit(batch, &mut report)?;
    }
    report.wall_secs = timer.elapsed_secs();
    Ok(report)
}

/// Drain `stream` into `sketch` through the fused pure-rust batch path:
/// accumulate fixed-size batches with [`Batcher`], insert each via
/// [`StormSketch::insert_batch`]. No compiled artifacts required, and the
/// resulting counters are bit-identical to scalar inserts (the batch
/// kernel's equivalence is property-tested in `proptest_invariants`).
pub fn rust_bulk_ingest(
    stream: &mut dyn StreamSource,
    batch_size: usize,
    sketch: &mut StormSketch,
) -> IngestReport {
    let timer = crate::util::timer::Timer::start();
    let mut batcher = Batcher::new(batch_size, StormSketch::dim(sketch));
    while let Some(example) = stream.next_example() {
        if let Some(batch) = batcher.push(example) {
            sketch.insert_batch(&batch);
        }
    }
    if let Some(batch) = batcher.finish() {
        sketch.insert_batch(&batch);
    }
    // The batcher already tracks what it emitted — no parallel tallies.
    IngestReport {
        examples: batcher.emitted_examples(),
        batches: batcher.emitted_batches(),
        executions: 0,
        wall_secs: timer.elapsed_secs(),
    }
}

#[cfg(test)]
mod tests {
    // The XLA path is exercised end-to-end (vs the scalar path,
    // bit-for-bit) in
    // rust/tests/integration_runtime.rs::bulk_ingest_matches_scalar_path;
    // unit-level batching behaviour is covered in batcher.rs.
    use super::*;
    use crate::config::StormConfig;
    use crate::data::dataset::Dataset;
    use crate::data::stream::ReplayStream;
    use crate::linalg::matrix::Matrix;

    fn toy_dataset(n: usize) -> Dataset {
        let x = Matrix::from_fn(n, 2, |r, c| ((r * 2 + c) % 7) as f64 * 0.1);
        let y = (0..n).map(|i| (i % 4) as f64 * 0.05).collect();
        Dataset::new("ingest", x, y)
    }

    #[test]
    fn rust_bulk_ingest_matches_scalar_inserts_bitwise() {
        // 53 = 6 full batches of 8 + a final short batch of 5 through
        // finish(): grid equality with the scalar path proves the short
        // batch was emitted exactly once and contributed exactly its own
        // counts — nothing from padding, nothing twice.
        let ds = toy_dataset(53);
        let cfg = StormConfig { rows: 12, power: 3, saturating: true, ..Default::default() };
        let mut bulk = crate::sketch::storm::StormSketch::new(cfg, 3, 77);
        let mut stream = ReplayStream::new(ds.clone());
        let report = rust_bulk_ingest(&mut stream, 8, &mut bulk);
        assert_eq!(report.examples, 53);
        assert_eq!(report.batches, 7); // ceil(53/8)
        let mut scalar = crate::sketch::storm::StormSketch::new(cfg, 3, 77);
        for i in 0..ds.len() {
            scalar.insert(&ds.augmented(i));
        }
        assert_eq!(bulk.grid().counts_u32(), scalar.grid().counts_u32());
        assert_eq!(bulk.count(), scalar.count());
    }

    #[test]
    fn rust_bulk_ingest_empty_stream() {
        let ds = toy_dataset(0);
        let cfg = StormConfig::default();
        let mut sk = crate::sketch::storm::StormSketch::new(cfg, 3, 1);
        let mut stream = ReplayStream::new(ds);
        let report = rust_bulk_ingest(&mut stream, 4, &mut sk);
        assert_eq!(report.examples, 0);
        assert_eq!(report.batches, 0);
        assert_eq!(sk.count(), 0);
    }
}
