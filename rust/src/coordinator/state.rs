//! Training-state checkpointing: theta, iteration, the per-iteration risk
//! trace, and the per-sync-round risk/bytes trace in a line-oriented text
//! format (no serde), with atomic replace.

use std::io::Write;
use std::path::Path;

/// Checkpointable training state.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainingState {
    pub dataset: String,
    pub iter: usize,
    pub theta: Vec<f64>,
    pub trace: Vec<(usize, f64)>,
    /// Per-sync-round `(round, risk, network bytes)` — the
    /// communication-vs-rounds curve of an online run. Empty for
    /// checkpoints written by one-shot runs (and by older versions of
    /// this format, which parse unchanged).
    pub rounds: Vec<(u64, f64, u64)>,
}

/// Checkpoint errors.
#[derive(Debug, thiserror::Error)]
pub enum StateError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("corrupt checkpoint: {0}")]
    Corrupt(String),
}

impl TrainingState {
    /// Serialize as lines: `dataset <name>`, `iter <n>`, `theta v v v...`,
    /// `trace i risk` per point, `round r risk bytes` per sync round.
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("dataset {}\n", self.dataset));
        s.push_str(&format!("iter {}\n", self.iter));
        s.push_str("theta");
        for v in &self.theta {
            s.push_str(&format!(" {v:.17e}"));
        }
        s.push('\n');
        for (i, r) in &self.trace {
            s.push_str(&format!("trace {i} {r:.17e}\n"));
        }
        for (round, risk, bytes) in &self.rounds {
            s.push_str(&format!("round {round} {risk:.17e} {bytes}\n"));
        }
        s
    }

    pub fn from_text(text: &str) -> Result<TrainingState, StateError> {
        let mut dataset = None;
        let mut iter = None;
        let mut theta = None;
        let mut trace = Vec::new();
        let mut rounds = Vec::new();
        for line in text.lines() {
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("dataset") => dataset = parts.next().map(str::to_string),
                Some("iter") => {
                    iter = Some(
                        parts
                            .next()
                            .and_then(|v| v.parse::<usize>().ok())
                            .ok_or_else(|| StateError::Corrupt("bad iter".into()))?,
                    )
                }
                Some("theta") => {
                    let vals: Result<Vec<f64>, _> = parts.map(str::parse::<f64>).collect();
                    theta = Some(vals.map_err(|_| StateError::Corrupt("bad theta".into()))?);
                }
                Some("trace") => {
                    let i = parts
                        .next()
                        .and_then(|v| v.parse::<usize>().ok())
                        .ok_or_else(|| StateError::Corrupt("bad trace iter".into()))?;
                    let r = parts
                        .next()
                        .and_then(|v| v.parse::<f64>().ok())
                        .ok_or_else(|| StateError::Corrupt("bad trace risk".into()))?;
                    trace.push((i, r));
                }
                Some("round") => {
                    let r = parts
                        .next()
                        .and_then(|v| v.parse::<u64>().ok())
                        .ok_or_else(|| StateError::Corrupt("bad round index".into()))?;
                    let risk = parts
                        .next()
                        .and_then(|v| v.parse::<f64>().ok())
                        .ok_or_else(|| StateError::Corrupt("bad round risk".into()))?;
                    let bytes = parts
                        .next()
                        .and_then(|v| v.parse::<u64>().ok())
                        .ok_or_else(|| StateError::Corrupt("bad round bytes".into()))?;
                    rounds.push((r, risk, bytes));
                }
                Some(other) => {
                    return Err(StateError::Corrupt(format!("unknown record {other:?}")))
                }
                None => {}
            }
        }
        Ok(TrainingState {
            dataset: dataset.ok_or_else(|| StateError::Corrupt("missing dataset".into()))?,
            iter: iter.ok_or_else(|| StateError::Corrupt("missing iter".into()))?,
            theta: theta.ok_or_else(|| StateError::Corrupt("missing theta".into()))?,
            trace,
            rounds,
        })
    }

    /// Atomic save: write to `<path>.tmp`, then rename.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), StateError> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(self.to_text().as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TrainingState, StateError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrainingState {
        TrainingState {
            dataset: "airfoil".to_string(),
            iter: 42,
            theta: vec![0.1, -0.25, 3.5e-7],
            trace: vec![(0, 1.0), (1, 0.5)],
            rounds: vec![(0, 0.9, 4096), (1, 0.4, 1024)],
        }
    }

    #[test]
    fn text_roundtrip_exact() {
        let s = sample();
        let back = TrainingState::from_text(&s.to_text()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("storm_state_test");
        let p = dir.join("ckpt.txt");
        let s = sample();
        s.save(&p).unwrap();
        assert_eq!(TrainingState::load(&p).unwrap(), s);
        // Overwrite is atomic-replace, not append.
        s.save(&p).unwrap();
        assert_eq!(TrainingState::load(&p).unwrap(), s);
    }

    #[test]
    fn corrupt_input_rejected() {
        assert!(TrainingState::from_text("garbage here\n").is_err());
        assert!(TrainingState::from_text("dataset a\niter x\ntheta 1\n").is_err());
        assert!(TrainingState::from_text("dataset a\n").is_err());
        assert!(TrainingState::from_text("dataset a\niter 1\ntheta 1\nround x 0.5 9\n").is_err());
        assert!(TrainingState::from_text("dataset a\niter 1\ntheta 1\nround 0 0.5\n").is_err());
    }

    #[test]
    fn legacy_checkpoints_without_rounds_still_parse() {
        let s = TrainingState::from_text("dataset a\niter 3\ntheta 1 2\ntrace 0 0.5\n").unwrap();
        assert!(s.rounds.is_empty());
        assert_eq!(s.theta, vec![1.0, 2.0]);
    }
}
