//! Risk oracles bridging the optimizer to the two execution backends.
//!
//! The pure-rust oracle is `StormSketch` itself (scalar queries). The XLA
//! oracle routes every risk evaluation through the AOT query executable —
//! and because the executable evaluates K query vectors per call, the DFO
//! optimizer's per-iteration probes are batched into a *single* PJRT
//! execution via [`XlaRiskOracle::risks`].

use crate::optim::RiskOracle;
use crate::runtime::XlaStorm;
use crate::sketch::storm::StormSketch;
use crate::util::mathx::norm2;
use std::cell::{Cell, RefCell};

/// Oracle that evaluates risks through the XLA query executable.
///
/// Scalar `risk()` calls are buffered per call (size-1 batches); the
/// batched entry point [`Self::risks`] evaluates many candidates in one
/// execution and is what the fused DFO loop uses.
pub struct XlaRiskOracle<'a> {
    exe: &'a XlaStorm,
    counts: Vec<u32>,
    n: u64,
    d: usize,
    evals: Cell<u64>,
    /// Executions performed (for the batching-efficiency metric).
    executions: Cell<u64>,
    last_error: RefCell<Option<String>>,
    /// Rescaled-candidate scratch reused across [`Self::risks`] calls —
    /// zero per-candidate allocation in the steady state, matching the
    /// convention of the sketch's `estimate_risk_batch`.
    scaled: RefCell<Vec<Vec<f64>>>,
}

impl<'a> XlaRiskOracle<'a> {
    /// Snapshot the sketch's counters into an oracle. `d` is the feature
    /// dimension (queries have length d + 1).
    pub fn new(exe: &'a XlaStorm, sketch: &StormSketch) -> Self {
        XlaRiskOracle {
            exe,
            counts: sketch.grid().counts_u32(),
            n: sketch.count(),
            d: StormSketch::dim(sketch) - 1,
            evals: Cell::new(0),
            executions: Cell::new(0),
            last_error: RefCell::new(None),
            scaled: RefCell::new(Vec::new()),
        }
    }

    /// Rescale a query into the unit ball exactly like the rust path,
    /// into a reusable buffer (cleared first).
    fn rescale_into(q: &[f64], out: &mut Vec<f64>) {
        let radius = crate::data::scale::query_radius();
        let n = norm2(q);
        out.clear();
        if n <= radius {
            out.extend_from_slice(q);
        } else {
            out.extend(q.iter().map(|v| v * radius / n));
        }
    }

    /// Batched risk evaluation: one PJRT execution for up to
    /// `exe.query_size()` candidates.
    pub fn risks(&self, candidates: &[Vec<f64>]) -> Vec<f64> {
        let mut out = Vec::with_capacity(candidates.len());
        let mut scaled = self.scaled.borrow_mut();
        for chunk in candidates.chunks(self.exe.query_size().max(1)) {
            // Rescale into long-lived scratch buffers instead of a fresh
            // Vec per candidate.
            if scaled.len() < chunk.len() {
                scaled.resize(chunk.len(), Vec::new());
            }
            for (slot, q) in scaled.iter_mut().zip(chunk) {
                Self::rescale_into(q, slot);
            }
            match self.exe.query_risks(&self.counts, self.n, &scaled[..chunk.len()]) {
                Ok(risks) => {
                    self.executions.set(self.executions.get() + 1);
                    self.evals.set(self.evals.get() + chunk.len() as u64);
                    out.extend(risks);
                }
                Err(e) => {
                    *self.last_error.borrow_mut() = Some(e.to_string());
                    out.extend(std::iter::repeat(f64::INFINITY).take(chunk.len()));
                }
            }
        }
        out
    }

    pub fn executions(&self) -> u64 {
        self.executions.get()
    }

    pub fn last_error(&self) -> Option<String> {
        self.last_error.borrow().clone()
    }
}

impl RiskOracle for XlaRiskOracle<'_> {
    fn risk(&self, theta_tilde: &[f64]) -> f64 {
        self.risks(std::slice::from_ref(&theta_tilde.to_vec()))[0]
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn evals(&self) -> u64 {
        self.evals.get()
    }

    /// Whole candidate sets map onto the K-wide compiled query entry
    /// point — one PJRT execution per `query_size` chunk instead of one
    /// per candidate.
    fn risk_batch(&self, candidates: &[Vec<f64>], out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.risks(candidates));
    }
}

/// A fused DFO step that batches the k antithetic probes into a single
/// XLA execution. The incumbent is never re-evaluated (the gradient uses
/// only central differences), so a step costs exactly `k` queries —
/// matching `DfoOptimizer::step`. Returns the new theta~ and the mean
/// probe risk (the sigma-smoothed risk estimate at the pre-step iterate).
pub fn fused_dfo_step(
    oracle: &XlaRiskOracle<'_>,
    theta_tilde: &mut Vec<f64>,
    queries: usize,
    sigma: f64,
    step: f64,
    rng: &mut crate::util::rng::Xoshiro256,
) -> f64 {
    use crate::util::mathx::axpy;
    use crate::util::rng::Rng;
    let dim = theta_tilde.len();
    let pairs = (queries / 2).max(1);
    let mut candidates = Vec::with_capacity(2 * pairs);
    let mut dirs = Vec::with_capacity(pairs);
    for _ in 0..pairs {
        let mut u = rng.sphere_vec(dim, 1.0);
        u[dim - 1] = 0.0;
        let mut plus = theta_tilde.clone();
        axpy(&mut plus, sigma, &u);
        let mut minus = theta_tilde.clone();
        axpy(&mut minus, -sigma, &u);
        candidates.push(plus);
        candidates.push(minus);
        dirs.push(u);
    }
    let risks = oracle.risks(&candidates);
    let mut grad = vec![0.0; dim];
    for (j, u) in dirs.iter().enumerate() {
        let delta = 0.5 * (risks[2 * j] - risks[2 * j + 1]);
        axpy(&mut grad, delta, u);
    }
    let scale = dim as f64 / (pairs as f64 * sigma);
    for g in &mut grad {
        *g *= scale;
    }
    let smoothed = risks.iter().sum::<f64>() / risks.len() as f64;
    axpy(theta_tilde, -step, &grad);
    theta_tilde[dim - 1] = -1.0;
    smoothed
}

#[cfg(test)]
mod tests {
    // The XLA-backed oracle is exercised by rust/tests/integration_runtime.rs
    // (requires `make artifacts`). Here we only test the rescale helper.
    use super::*;

    #[test]
    fn rescale_preserves_direction() {
        let q = vec![3.0, 4.0];
        let mut s = vec![9.0; 7]; // stale scratch must be overwritten
        XlaRiskOracle::rescale_into(&q, &mut s);
        let n = norm2(&s);
        assert!((n - crate::data::scale::query_radius()).abs() < 1e-12);
        assert!((s[0] / s[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn rescale_noop_inside_ball() {
        let q = vec![0.1, 0.1];
        let mut s = Vec::new();
        XlaRiskOracle::rescale_into(&q, &mut s);
        assert_eq!(s, q);
    }
}
