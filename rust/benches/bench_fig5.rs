//! Figure 5 regeneration: the 2-D qualitative experiments (regression
//! with PRP p=4, classification with the margin loss p=1; R=100, 100 DFO
//! iterations — the paper's settings).

use storm::experiments::{fig5, Effort};
use storm::util::bench::{section, JsonReporter};

fn main() {
    let effort = Effort::from_env();
    section("fig5: 2-D synthetic regression + classification");
    for table in fig5::run(effort, 0) {
        table.print();
        println!();
    }

    let mut json = JsonReporter::new("fig5");
    json.record_peak_rss();
    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_fig5.json: {e}"),
    }
}
