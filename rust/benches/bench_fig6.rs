//! Figure 6 regeneration: STORM's margin loss vs classical losses.

use storm::experiments::fig6;
use storm::util::bench::{section, JsonReporter};

fn main() {
    section("fig6: classification losses");
    fig6::run().print();

    let mut json = JsonReporter::new("fig6");
    json.record_peak_rss();
    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_fig6.json: {e}"),
    }
}
