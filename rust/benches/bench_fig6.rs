//! Figure 6 regeneration: STORM's margin loss vs classical losses.

use storm::experiments::fig6;
use storm::util::bench::section;

fn main() {
    section("fig6: classification losses");
    fig6::run().print();
}
