//! Sketch micro-benchmarks: insert throughput (fused hash-bank batch
//! path vs the seed scalar path), query latency (scalar and batched),
//! merge and wire-format throughput — the L3 hot-path numbers for
//! EXPERIMENTS.md §Perf. Run with `cargo bench --bench bench_sketch`;
//! set `STORM_BENCH_FAST=1` for a quick pass. Alongside the human
//! output, results are written to `BENCH_sketch.json` (see
//! `storm::util::bench::JsonReporter`) so the perf trajectory is tracked
//! across PRs.

use storm::config::{CounterWidth, HashFamily, StormConfig};
use storm::lsh::bank::HashBank;
use storm::lsh::prp::PairedRandomProjection;
use storm::lsh::query::{CandidateSet, Probe, QueryEngine};
use storm::optim::dfo::{DfoConfig, DfoOptimizer};
use storm::optim::IncrementalOracle;
use storm::sketch::model::StormModel;
use storm::sketch::serialize::{
    decode, decode_delta, delta_wire_bytes, encode, encode_delta, wire_bytes,
};
use storm::sketch::storm::StormSketch;
use storm::sketch::RiskSketch;
use storm::testing::gen_ball_point;
use storm::util::bench::{bench_items, black_box, config_from_env, section, JsonReporter};
use storm::util::rng::Xoshiro256;

fn main() {
    let cfg = config_from_env();
    let mut json = JsonReporter::new("sketch");

    section("sketch: insert throughput (fused hash-bank batch path)");
    for (rows, power) in [(50usize, 4u32), (100, 4), (400, 4), (100, 8)] {
        let scfg = StormConfig { rows, power, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(1);
        let data: Vec<Vec<f64>> = (0..1024).map(|_| gen_ball_point(&mut rng, 22, 0.9)).collect();
        let mut sk = StormSketch::new(scfg, 22, 7);
        json.record(bench_items(
            &format!("insert_1k_R{rows}_p{power}_d22"),
            cfg,
            data.len() as u64,
            || {
                sk.insert_batch(&data);
            },
        ));
    }

    section("sketch: insert throughput (seed scalar path, for comparison)");
    for (rows, power) in [(100usize, 4u32)] {
        let scfg = StormConfig { rows, power, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(1);
        let data: Vec<Vec<f64>> = (0..1024).map(|_| gen_ball_point(&mut rng, 22, 0.9)).collect();
        let mut sk = StormSketch::new(scfg, 22, 7);
        json.record(bench_items(
            &format!("insert_scalar_1k_R{rows}_p{power}_d22"),
            cfg,
            data.len() as u64,
            || {
                for z in &data {
                    sk.insert(z);
                }
            },
        ));
    }

    section("lsh bank: projection-kernel throughput (items = row-hashes)");
    // The kernel matrix of the hash hot path: the same 100-row bank at
    // d = 64, p = 8, hashed by (a) the scalar oracle, (b) the
    // runtime-dispatched SIMD kernel, and the two structured families.
    // Per item = one row's pair of PRP buckets, so items/sec compares
    // projection engines directly, independent of counter traffic.
    {
        let (rows, d, p) = (100usize, 64usize, 8u32);
        let prp_rows: Vec<PairedRandomProjection> = (0..rows)
            .map(|r| PairedRandomProjection::new(d, p, 0x9E37 + r as u64))
            .collect();
        let dense_bank = HashBank::from_rows(&prp_rows);
        println!("  dense kernel: {}", dense_bank.kernel_name());
        let seeds: Vec<u64> = (0..rows)
            .map(|r| 7u64.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(r as u64))
            .collect();
        let sparse_bank = HashBank::sparse_from_seeds(d, p, &seeds, 100);
        let hadamard_bank = HashBank::hadamard_from_seeds(d, p, &seeds);
        let mut rng = Xoshiro256::new(8);
        let batch: Vec<Vec<f64>> = (0..256).map(|_| gen_ball_point(&mut rng, d, 0.9)).collect();
        let tails: Vec<f64> = batch.iter().map(|z| HashBank::mips_tail(z)).collect();
        let n_hashes = (rows * batch.len()) as u64;
        let sweep = |bank: &HashBank, scalar: bool| {
            let mut acc = 0usize;
            for (z, &t) in batch.iter().zip(&tails) {
                for r in 0..rows {
                    let (a, b) = if scalar {
                        bank.data_pair_scalar(r, z, t)
                    } else {
                        bank.data_pair(r, z, t)
                    };
                    acc ^= a ^ b;
                }
            }
            black_box(acc);
        };
        json.record(bench_items("bank_scalar_pair_R100_d64_p8", cfg, n_hashes, || {
            sweep(&dense_bank, true);
        }));
        json.record(bench_items("bank_simd_pair_R100_d64_p8", cfg, n_hashes, || {
            sweep(&dense_bank, false);
        }));
        json.record(bench_items("bank_sparse_pair_R100_d64_p8", cfg, n_hashes, || {
            sweep(&sparse_bank, false);
        }));
        json.record(bench_items("bank_hadamard_pair_R100_d64_p8", cfg, n_hashes, || {
            sweep(&hadamard_bank, false);
        }));
        // Query side (single bucket per row) for the dense kernels only —
        // the structured families share their data-side code path.
        let q = gen_ball_point(&mut rng, d, 0.8);
        let qt = HashBank::mips_tail(&q);
        json.record(bench_items("bank_scalar_query_R100_d64_p8", cfg, rows as u64, || {
            let mut acc = 0usize;
            for r in 0..rows {
                acc ^= dense_bank.query_bucket_scalar(r, &q, qt);
            }
            black_box(acc);
        }));
        json.record(bench_items("bank_simd_query_R100_d64_p8", cfg, rows as u64, || {
            let mut acc = 0usize;
            for r in 0..rows {
                acc ^= dense_bank.query_bucket(r, &q, qt);
            }
            black_box(acc);
        }));
    }

    section("sketch: query latency");
    for rows in [50usize, 100, 400] {
        let scfg = StormConfig { rows, power: 4, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(2);
        let mut sk = StormSketch::new(scfg, 22, 7);
        for _ in 0..2000 {
            let z = gen_ball_point(&mut rng, 22, 0.9);
            sk.insert(&z);
        }
        let q = gen_ball_point(&mut rng, 22, 0.8);
        json.record(bench_items(&format!("query_R{rows}_d22"), cfg, 1, || {
            black_box(sk.estimate_risk(&q));
        }));
        // Batched candidate-set evaluation (the DFO per-iteration shape):
        // one risk per candidate, fused bank kernel, scratch reuse.
        let cands: Vec<Vec<f64>> =
            (0..64).map(|_| gen_ball_point(&mut rng, 22, 0.8)).collect();
        let mut out = Vec::new();
        json.record(bench_items(
            &format!("risk_batch_64_R{rows}_d22"),
            cfg,
            cands.len() as u64,
            || {
                sk.estimate_risk_batch(&cands, &mut out);
                black_box(out.len());
            },
        ));
    }

    section("sketch: merge + wire format");
    let scfg = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };
    let mut rng = Xoshiro256::new(3);
    let mut a = StormSketch::new(scfg, 22, 9);
    let mut b = StormSketch::new(scfg, 22, 9);
    for _ in 0..1000 {
        a.insert(&gen_ball_point(&mut rng, 22, 0.9));
        b.insert(&gen_ball_point(&mut rng, 22, 0.9));
    }
    json.record(bench_items("merge_R100", cfg, 1, || {
        let mut c = a.grid().clone();
        c.merge_from(black_box(b.grid()));
        black_box(c.total());
    }));
    let bytes = encode(&a);
    json.record(bench_items("wire_encode_R100", cfg, bytes.len() as u64, || {
        black_box(encode(&a));
    }));
    json.record(bench_items("wire_decode_R100", cfg, bytes.len() as u64, || {
        black_box(decode(&bytes).unwrap());
    }));

    section("sketch: delta wire format + merge (sync rounds)");
    // A QUIET round: 2 fresh examples on a warm device touch at most
    // 2 * 2 * R of the R * 16 cells (25%), so the encoder goes sparse —
    // this is the regime where v2 beats shipping a dense frame. (At
    // p = 4 every insert bumps 2 cells per row, so rounds past ~4
    // examples populate > 50% of cells and take the dense fallback;
    // that busy regime is measured separately below.)
    let snap = a.snapshot();
    for _ in 0..2 {
        a.insert(&gen_ball_point(&mut rng, 22, 0.9));
    }
    let quiet = a.delta_since(&snap, 1);
    assert!(quiet.populated_fraction() <= 0.5, "quiet round must be sparse");
    let sparse = encode_delta(&quiet);
    json.record_scalar("delta_wire_bytes_sparse_2ex_R100", sparse.len() as f64);
    json.record_scalar(
        "delta_populated_fraction_2ex_R100",
        quiet.populated_fraction(),
    );
    // A BUSY round: 64 examples populate essentially every cell, so the
    // encoder falls back to the dense v2 layout (~= v1 + 9 header bytes).
    let snap = a.snapshot();
    for _ in 0..64 {
        a.insert(&gen_ball_point(&mut rng, 22, 0.9));
    }
    let busy = a.delta_since(&snap, 2);
    let dense = encode_delta(&busy);
    json.record_scalar("delta_wire_bytes_dense_64ex_R100", dense.len() as f64);
    json.record_scalar("delta_wire_bytes_dense_v1_R100", wire_bytes(&scfg) as f64);
    json.record(bench_items("delta_encode_sparse_R100", cfg, sparse.len() as u64, || {
        black_box(encode_delta(&quiet));
    }));
    json.record(bench_items("delta_decode_sparse_R100", cfg, sparse.len() as u64, || {
        black_box(decode_delta(&sparse).unwrap());
    }));
    json.record(bench_items("delta_encode_dense_R100", cfg, dense.len() as u64, || {
        black_box(encode_delta(&busy));
    }));
    // Aggregator fold: merge a round's delta into an accumulator.
    let other = busy.clone();
    json.record(bench_items(
        "delta_merge_R100",
        cfg,
        busy.counts.len() as u64,
        || {
            let mut acc = busy.clone();
            acc.merge_from(&other);
            black_box(acc.count);
        },
    ));
    // Leader apply: fold a round's delta into the live sketch.
    let mut leader = StormSketch::new(scfg, 22, 9);
    json.record(bench_items(
        "delta_apply_R100",
        cfg,
        busy.counts.len() as u64,
        || {
            leader.apply_delta(&busy);
            black_box(leader.count());
        },
    ));

    section("sketch: private release + leader decay (delta-level DP)");
    // Noised-delta encode: the two-sided geometric mechanism draws one
    // integer per counter cell before encode, and noising zero cells
    // densifies a frame — both overheads land here. EXPERIMENTS.md
    // §Privacy + drift reads these scalars.
    let mut noised = busy.clone();
    storm::sketch::privacy::noise_delta(&mut noised, 0.5, 0xBE9C);
    json.record_scalar("delta_wire_bytes_noised_eps05_64ex_R100", encode_delta(&noised).len() as f64);
    json.record(bench_items(
        "delta_noise_and_encode_eps05_R100",
        cfg,
        busy.counts.len() as u64,
        || {
            let mut d = busy.clone();
            storm::sketch::privacy::noise_delta(&mut d, 0.5, 0xBE9C);
            black_box(encode_delta(&d));
        },
    ));
    // Decayed fold: the leader's per-round floor(c * keep / 1000) pass.
    json.record(bench_items("leader_decay_keep900_R100", cfg, (100 * 16) as u64, || {
        leader.decay(900);
        black_box(leader.count());
    }));

    section("sketch: counter-width tiers (u8 / u16 / u32)");
    // The width sweep: same geometry, same stream, three cell widths —
    // memory and dense-wire bytes scale 1:2:4 while the hash work is
    // identical, so insert/query throughput shows the pure effect of the
    // narrower counter buffer (smaller working set vs the widening read).
    for width in [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32] {
        let scfg = StormConfig {
            rows: 100,
            power: 4,
            saturating: true,
            counter_width: width,
            ..Default::default()
        };
        let mut rng = Xoshiro256::new(5);
        let data: Vec<Vec<f64>> =
            (0..1024).map(|_| gen_ball_point(&mut rng, 22, 0.9)).collect();
        let mut sk = StormSketch::new(scfg, 22, 7);
        json.record(bench_items(
            &format!("sketch_width_{width}_insert_1k_R100"),
            cfg,
            data.len() as u64,
            || {
                sk.insert_batch(&data);
            },
        ));
        let q = gen_ball_point(&mut rng, 22, 0.8);
        json.record(bench_items(&format!("sketch_width_{width}_query_R100"), cfg, 1, || {
            black_box(sk.estimate_risk(&q));
        }));
        json.record_scalar(&format!("sketch_width_{width}_bytes_R100"), sk.bytes() as f64);
        json.record_scalar(
            &format!("sketch_width_{width}_dense_delta_wire_bytes_R100"),
            delta_wire_bytes(&scfg) as f64,
        );
        let snap = sk.snapshot();
        for _ in 0..2 {
            sk.insert(&gen_ball_point(&mut rng, 22, 0.9));
        }
        json.record_scalar(
            &format!("sketch_width_{width}_sparse_delta_wire_bytes_2ex_R100"),
            encode_delta(&sk.delta_since(&snap, 1)).len() as f64,
        );
    }

    section("sketch: optimizer candidate queries (rank-1 incremental vs dense)");
    // One optimizer step's candidate set at the paper-scale geometry
    // (R = 100, p = 4, d = 256): 64 axis probes — a coordinate-descent
    // bracket sweep, the engine's best case. The dense path materializes
    // every candidate and re-projects it from scratch
    // (~R * p * (d + 2) mul-adds each); the incremental engine projects
    // the incumbent once and serves each probe as an O(R * p) rank-1
    // update. EXPERIMENTS.md §Perf "optimizer query cost" reads the
    // speedup scalars.
    let d = 256usize;
    for (name, family) in [
        ("dense", HashFamily::Dense),
        ("sparse", HashFamily::Sparse { density_permille: 300 }),
        ("hadamard", HashFamily::Hadamard),
    ] {
        let scfg = StormConfig {
            rows: 100,
            power: 4,
            saturating: true,
            hash_family: family,
            ..Default::default()
        };
        let mut rng = Xoshiro256::new(6);
        let mut model = StormModel::new(scfg, d + 1, 7);
        let data: Vec<Vec<f64>> =
            (0..256).map(|_| gen_ball_point(&mut rng, d + 1, 0.9)).collect();
        model.insert_batch(&data);
        let mut base = gen_ball_point(&mut rng, d, 0.7);
        base.push(-1.0);
        let probes: Vec<Probe> = (0..32)
            .flat_map(|j| [Probe::Axis { k: j, value: 0.3 }, Probe::Axis { k: j, value: -0.3 }])
            .collect();
        let set = CandidateSet { base: &base, dirs: &[], probes: &probes };
        let mut out = Vec::new();
        let mut dense_cands = Vec::new();
        let dense_res = bench_items(
            &format!("oracle_step_dense_{name}_R100_d256"),
            cfg,
            probes.len() as u64,
            || {
                set.materialize(&mut dense_cands);
                model.estimate_risk_batch(&dense_cands, &mut out);
                black_box(out.len());
            },
        );
        let mut engine = QueryEngine::new(model.bank());
        let inc_res = bench_items(
            &format!("oracle_step_incremental_{name}_R100_d256"),
            cfg,
            probes.len() as u64,
            || {
                model.estimate_risk_candidates(&mut engine, &set, &mut out);
                black_box(out.len());
            },
        );
        json.record_scalar(
            &format!("oracle_step_speedup_{name}_R100_d256"),
            dense_res.mean_s / inc_res.mean_s,
        );
        json.record(dense_res);
        json.record(inc_res);
    }
    // A whole fused DFO step (k = 8 sphere probes, 4 antithetic pairs)
    // at the same geometry: the incumbent moves every step, so each
    // iteration pays one fresh base projection plus 4 direction
    // projections shared across their antithetic pairs — the realistic
    // per-step win (~2x) rather than the axis-sweep best case.
    {
        let scfg = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };
        let mut rng = Xoshiro256::new(6);
        let mut model = StormModel::new(scfg, d + 1, 7);
        let data: Vec<Vec<f64>> =
            (0..256).map(|_| gen_ball_point(&mut rng, d + 1, 0.9)).collect();
        model.insert_batch(&data);
        let ocfg = DfoConfig { queries: 8, sigma: 0.2, step: 0.02, iters: 1, seed: 11 };
        let mut opt = DfoOptimizer::new(ocfg, d);
        let dense_res = bench_items("dfo_step_dense_R100_d256", cfg, 8, || {
            black_box(opt.step(&model));
        });
        let oracle = IncrementalOracle::new(&model);
        let mut opt = DfoOptimizer::new(ocfg, d);
        let inc_res = bench_items("dfo_step_incremental_R100_d256", cfg, 8, || {
            black_box(opt.step(&oracle));
        });
        json.record_scalar("dfo_step_speedup_R100_d256", dense_res.mean_s / inc_res.mean_s);
        json.record(dense_res);
        json.record(inc_res);
    }

    json.record_peak_rss();
    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_sketch.json: {e}"),
    }
}
