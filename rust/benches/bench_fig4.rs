//! Figure 4 regeneration: the full memory sweep (STORM vs random
//! sampling vs leverage sampling vs Clarkson–Woodruff) on the three
//! Table-1 datasets. Fast effort by default; set `STORM_BENCH_FULL=1`
//! for the paper protocol (10 runs per point).

use storm::experiments::{fig4, Effort};
use storm::util::bench::{section, JsonReporter};
use storm::util::timer::Timer;

fn main() {
    let effort = Effort::from_env();
    section(&format!("fig4: MSE vs memory ({effort:?} effort)"));
    let t = Timer::start();
    for table in fig4::run(effort, 0) {
        table.print();
        println!();
    }
    println!("# fig4 total wall: {:.1}s", t.elapsed_secs());

    let mut json = JsonReporter::new("fig4");
    json.record_scalar("fig4_wall_secs", t.elapsed_secs());
    json.record_peak_rss();
    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_fig4.json: {e}"),
    }
}
