//! Fleet benchmarks: end-to-end sketch aggregation throughput across
//! device counts and topologies, plus the merge/backpressure profile —
//! regenerates the mergeability experiment numbers.

use storm::config::{FleetConfig, StormConfig};
use storm::data::scale::scale_to_unit_ball;
use storm::data::stream::partition_streams;
use storm::data::synthetic;
use storm::edge::fleet::run_fleet;
use storm::edge::topology::Topology;
use storm::experiments::{merge, Effort};
use storm::util::bench::{bench_items, config_from_env, section};

fn main() {
    let cfg = config_from_env();
    let mut ds = synthetic::parkinsons(5);
    scale_to_unit_ball(&mut ds, 0.9);
    let storm_cfg = StormConfig { rows: 100, power: 4, saturating: true };

    section("fleet: ingest throughput vs devices (star)");
    for devices in [1usize, 2, 4, 8] {
        let n = ds.len() as u64;
        let dsc = ds.clone();
        bench_items(&format!("fleet_star_{devices}dev_5800ex"), cfg, n, || {
            let fleet = FleetConfig {
                devices,
                batch: 64,
                channel_capacity: 8,
                link_latency_us: 0,
                link_bandwidth_bps: 0,
                seed: 0,
            };
            let streams = partition_streams(&dsc, devices, None);
            let r = run_fleet(fleet, storm_cfg, Topology::Star, dsc.dim() + 1, 3, streams);
            assert_eq!(r.examples, n);
        });
    }

    section("fleet: topology comparison (8 devices)");
    for (name, topo) in [
        ("star", Topology::Star),
        ("tree2", Topology::Tree { fanout: 2 }),
        ("chain", Topology::Chain),
    ] {
        let n = ds.len() as u64;
        let dsc = ds.clone();
        bench_items(&format!("fleet_{name}_8dev"), cfg, n, || {
            let fleet = FleetConfig {
                devices: 8,
                batch: 64,
                channel_capacity: 8,
                link_latency_us: 0,
                link_bandwidth_bps: 0,
                seed: 0,
            };
            let streams = partition_streams(&dsc, 8, None);
            let r = run_fleet(fleet, storm_cfg, topo, dsc.dim() + 1, 3, streams);
            assert_eq!(r.examples, n);
        });
    }

    section("merge experiment table");
    merge::run(Effort::from_env(), 5).print();
}
