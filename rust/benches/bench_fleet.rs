//! Fleet benchmarks: end-to-end sketch aggregation throughput across
//! device counts, topologies and sync-round counts, plus the
//! merge/backpressure profile — regenerates the mergeability experiment
//! numbers and the communication-vs-rounds curve. Alongside the human
//! output, results land in `BENCH_fleet.json` (see
//! `storm::util::bench::JsonReporter`; EXPERIMENTS.md §Communication vs.
//! rounds reads it).

use storm::config::{CounterWidth, FleetConfig, StormConfig};
use storm::data::scale::scale_to_unit_ball;
use storm::data::stream::{partition_streams, Example, StreamSource};
use storm::data::synthetic;
use storm::edge::faults::FaultPlan;
use storm::edge::fleet::{run_fleet, run_fleet_chaos};
use storm::edge::topology::Topology;
use storm::experiments::{merge, Effort};
use storm::util::bench::{bench_items, config_from_env, peak_rss_bytes, section, JsonReporter};

fn fleet_cfg(devices: usize, sync_rounds: usize) -> FleetConfig {
    FleetConfig {
        devices,
        batch: 64,
        channel_capacity: 8,
        link_latency_us: 0,
        link_bandwidth_bps: 0,
        sync_rounds,
        min_quorum: 0,
        faults_seed: None,
        device_counter_width: None,
        workers: 0,
        fan_in: 2,
        epsilon_per_round: 0.0,
        decay_keep_permille: 1000,
        seed: 0,
    }
}

/// Cheap procedural per-device stream for the scale sweep: a handful of
/// examples drawn from a splitmix64 generator, so a million devices cost
/// a few machine words of stream state each instead of a million dataset
/// shards.
struct SynthStream {
    left: usize,
    state: u64,
    dim: usize,
}

impl SynthStream {
    fn new(device: u64, dim: usize, n: usize) -> SynthStream {
        SynthStream {
            left: n,
            state: device.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0xD1B5_4A32_D192_ED03),
            dim,
        }
    }

    /// splitmix64 step mapped to [-0.5, 0.5).
    fn next_f64(&mut self) -> f64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64 - 0.5
    }
}

impl StreamSource for SynthStream {
    fn next_example(&mut self) -> Option<Example> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        Some((0..self.dim).map(|_| self.next_f64()).collect())
    }

    fn remaining_hint(&self) -> Option<usize> {
        Some(self.left)
    }
}

fn main() {
    let cfg = config_from_env();
    let mut json = JsonReporter::new("fleet");
    let mut ds = synthetic::parkinsons(5);
    scale_to_unit_ball(&mut ds, 0.9);
    let storm_cfg = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };

    section("fleet: ingest throughput vs devices (star)");
    for devices in [1usize, 2, 4, 8] {
        let n = ds.len() as u64;
        let dsc = ds.clone();
        json.record(bench_items(
            &format!("fleet_star_{devices}dev_5800ex"),
            cfg,
            n,
            || {
                let streams = partition_streams(&dsc, devices, None);
                let r = run_fleet(
                    fleet_cfg(devices, 1),
                    storm_cfg,
                    Topology::Star,
                    dsc.dim() + 1,
                    3,
                    streams,
                );
                assert_eq!(r.examples, n);
            },
        ));
    }

    section("fleet: topology comparison (8 devices)");
    for (name, topo) in [
        ("star", Topology::Star),
        ("tree2", Topology::Tree { fanout: 2 }),
        ("chain", Topology::Chain),
    ] {
        let n = ds.len() as u64;
        let dsc = ds.clone();
        json.record(bench_items(&format!("fleet_{name}_8dev"), cfg, n, || {
            let streams = partition_streams(&dsc, 8, None);
            let r = run_fleet(fleet_cfg(8, 1), storm_cfg, topo, dsc.dim() + 1, 3, streams);
            assert_eq!(r.examples, n);
        }));
    }

    section("fleet: delta sync rounds (4 devices, star)");
    for rounds in [1usize, 4, 16] {
        let n = ds.len() as u64;
        let dsc = ds.clone();
        json.record(bench_items(
            &format!("fleet_star_4dev_{rounds}rounds"),
            cfg,
            n,
            || {
                let streams = partition_streams(&dsc, 4, None);
                let r = run_fleet(
                    fleet_cfg(4, rounds),
                    storm_cfg,
                    Topology::Star,
                    dsc.dim() + 1,
                    3,
                    streams,
                );
                assert_eq!(r.examples, n);
                assert_eq!(r.rounds.len(), rounds);
            },
        ));
        // Wire cost of the same workload at this round count (one run,
        // deterministic): the communication-vs-rounds curve.
        let streams = partition_streams(&ds, 4, None);
        let r = run_fleet(
            fleet_cfg(4, rounds),
            storm_cfg,
            Topology::Star,
            ds.dim() + 1,
            3,
            streams,
        );
        json.record_scalar(&format!("fleet_net_bytes_4dev_{rounds}rounds"), r.network.bytes as f64);
        json.record_scalar(
            &format!("fleet_net_msgs_4dev_{rounds}rounds"),
            r.network.messages as f64,
        );
    }

    section("fleet: catch-up overhead vs drop rate (4 devices, star, 8 rounds)");
    // EXPERIMENTS.md §Resilience reads these scalars: at each controlled
    // drop rate, how many catch-up (retransmit) bytes the protocol
    // spends recovering the stream, as a fraction of total wire bytes.
    // The merged counters are asserted bit-identical to the loss-free
    // run — resilience costs bytes, never correctness.
    let baseline = {
        let streams = partition_streams(&ds, 4, None);
        run_fleet(fleet_cfg(4, 8), storm_cfg, Topology::Star, ds.dim() + 1, 3, streams)
    };
    for drop_per_mille in [0u16, 50, 100, 200, 400] {
        let plan = (drop_per_mille > 0).then(|| FaultPlan::drop_only(9, drop_per_mille));
        let streams = partition_streams(&ds, 4, None);
        let r = run_fleet_chaos(
            fleet_cfg(4, 8),
            storm_cfg,
            Topology::Star,
            ds.dim() + 1,
            3,
            streams,
            plan,
            |_, _| {},
        );
        assert_eq!(
            r.sketch.grid().counts_u32(),
            baseline.sketch.grid().counts_u32(),
            "drop rate {drop_per_mille} per-mille changed the counters"
        );
        json.record_scalar(
            &format!("fleet_chaos_net_bytes_drop{drop_per_mille}pm"),
            r.network.bytes as f64,
        );
        json.record_scalar(
            &format!("fleet_chaos_retransmit_bytes_drop{drop_per_mille}pm"),
            r.network.retransmit_bytes() as f64,
        );
        json.record_scalar(
            &format!("fleet_chaos_drops_drop{drop_per_mille}pm"),
            r.faults.drops as f64,
        );
    }

    section("fleet: private deltas + decayed leader (4 devices, star, 8 rounds)");
    // EXPERIMENTS.md §Privacy + drift reads these scalars: the wire
    // overhead of noised v3 frames (noising zero cells densifies a
    // sparse round) and the wall cost of the leader's per-round decay
    // pass, each against the same exact baseline run.
    {
        let streams = partition_streams(&ds, 4, None);
        let exact =
            run_fleet(fleet_cfg(4, 8), storm_cfg, Topology::Star, ds.dim() + 1, 3, streams);
        let mut pcfg = fleet_cfg(4, 8);
        pcfg.epsilon_per_round = 0.5;
        let streams = partition_streams(&ds, 4, None);
        let private = run_fleet(pcfg, storm_cfg, Topology::Star, ds.dim() + 1, 3, streams);
        assert_eq!(private.examples, exact.examples, "DP must not drop examples");
        json.record_scalar("fleet_net_bytes_exact_4dev_8rounds", exact.network.bytes as f64);
        json.record_scalar(
            "fleet_net_bytes_private_eps05_4dev_8rounds",
            private.network.bytes as f64,
        );
        json.record_scalar("fleet_wall_secs_exact_4dev_8rounds", exact.wall_secs);
        json.record_scalar("fleet_wall_secs_private_eps05_4dev_8rounds", private.wall_secs);
        let mut dcfg = fleet_cfg(4, 8);
        dcfg.decay_keep_permille = 900;
        let streams = partition_streams(&ds, 4, None);
        let decayed = run_fleet(dcfg, storm_cfg, Topology::Star, ds.dim() + 1, 3, streams);
        assert_eq!(decayed.examples, exact.examples, "decay must not drop examples");
        json.record_scalar("fleet_wall_secs_decay900_4dev_8rounds", decayed.wall_secs);
    }

    section("fleet: scale sweep (worker-pool executor, arena device state)");
    // The EXPERIMENTS.md §Scale sweep table reads these scalars. Each
    // tier is one deterministic run of the pooled executor — u8 device
    // counters, 4 examples/device, 2 sync rounds — on a star and on a
    // fan-in-capped deep tree. The 100k and 1M tiers are skipped under
    // STORM_BENCH_FAST (CI runs the 10k tier only).
    let fast = std::env::var("STORM_BENCH_FAST").is_ok();
    let tiers: &[usize] = if fast { &[10_000] } else { &[10_000, 100_000, 1_000_000] };
    let scale_storm = StormConfig { rows: 8, power: 3, saturating: true, ..Default::default() };
    let (dim, per_device, rounds) = (4usize, 4usize, 2usize);
    for &devices in tiers {
        for (tname, topo) in
            [("star", Topology::Star), ("deep16", Topology::Deep { max_fan_in: 16 })]
        {
            let mut scfg = fleet_cfg(devices, rounds);
            scfg.batch = 4;
            scfg.device_counter_width = Some(CounterWidth::U8);
            let streams: Vec<Box<dyn StreamSource>> = (0..devices)
                .map(|d| {
                    Box::new(SynthStream::new(d as u64, dim, per_device)) as Box<dyn StreamSource>
                })
                .collect();
            let r = run_fleet(scfg, scale_storm, topo, dim, 11, streams);
            assert_eq!(r.examples, (per_device * devices) as u64);
            assert_eq!(r.rounds.len(), rounds);
            let label = format!("fleet_scale_{tname}_{devices}dev");
            json.record_scalar(&format!("{label}_rounds_per_sec"), rounds as f64 / r.wall_secs);
            json.record_scalar(
                &format!("{label}_bytes_per_round"),
                r.network.bytes as f64 / rounds as f64,
            );
            json.record_scalar(&format!("{label}_peak_rss_bytes"), peak_rss_bytes() as f64);
        }
    }

    section("merge experiment table");
    merge::run(Effort::from_env(), 5).print();

    json.record_peak_rss();
    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_fleet.json: {e}"),
    }
}
