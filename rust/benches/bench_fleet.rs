//! Fleet benchmarks: end-to-end sketch aggregation throughput across
//! device counts, topologies and sync-round counts, plus the
//! merge/backpressure profile — regenerates the mergeability experiment
//! numbers and the communication-vs-rounds curve. Alongside the human
//! output, results land in `BENCH_fleet.json` (see
//! `storm::util::bench::JsonReporter`; EXPERIMENTS.md §Communication vs.
//! rounds reads it).

use storm::config::{FleetConfig, StormConfig};
use storm::data::scale::scale_to_unit_ball;
use storm::data::stream::partition_streams;
use storm::data::synthetic;
use storm::edge::faults::FaultPlan;
use storm::edge::fleet::{run_fleet, run_fleet_chaos};
use storm::edge::topology::Topology;
use storm::experiments::{merge, Effort};
use storm::util::bench::{bench_items, config_from_env, section, JsonReporter};

fn fleet_cfg(devices: usize, sync_rounds: usize) -> FleetConfig {
    FleetConfig {
        devices,
        batch: 64,
        channel_capacity: 8,
        link_latency_us: 0,
        link_bandwidth_bps: 0,
        sync_rounds,
        min_quorum: 0,
        faults_seed: None,
        device_counter_width: None,
        seed: 0,
    }
}

fn main() {
    let cfg = config_from_env();
    let mut json = JsonReporter::new("fleet");
    let mut ds = synthetic::parkinsons(5);
    scale_to_unit_ball(&mut ds, 0.9);
    let storm_cfg = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };

    section("fleet: ingest throughput vs devices (star)");
    for devices in [1usize, 2, 4, 8] {
        let n = ds.len() as u64;
        let dsc = ds.clone();
        json.record(bench_items(
            &format!("fleet_star_{devices}dev_5800ex"),
            cfg,
            n,
            || {
                let streams = partition_streams(&dsc, devices, None);
                let r = run_fleet(
                    fleet_cfg(devices, 1),
                    storm_cfg,
                    Topology::Star,
                    dsc.dim() + 1,
                    3,
                    streams,
                );
                assert_eq!(r.examples, n);
            },
        ));
    }

    section("fleet: topology comparison (8 devices)");
    for (name, topo) in [
        ("star", Topology::Star),
        ("tree2", Topology::Tree { fanout: 2 }),
        ("chain", Topology::Chain),
    ] {
        let n = ds.len() as u64;
        let dsc = ds.clone();
        json.record(bench_items(&format!("fleet_{name}_8dev"), cfg, n, || {
            let streams = partition_streams(&dsc, 8, None);
            let r = run_fleet(fleet_cfg(8, 1), storm_cfg, topo, dsc.dim() + 1, 3, streams);
            assert_eq!(r.examples, n);
        }));
    }

    section("fleet: delta sync rounds (4 devices, star)");
    for rounds in [1usize, 4, 16] {
        let n = ds.len() as u64;
        let dsc = ds.clone();
        json.record(bench_items(
            &format!("fleet_star_4dev_{rounds}rounds"),
            cfg,
            n,
            || {
                let streams = partition_streams(&dsc, 4, None);
                let r = run_fleet(
                    fleet_cfg(4, rounds),
                    storm_cfg,
                    Topology::Star,
                    dsc.dim() + 1,
                    3,
                    streams,
                );
                assert_eq!(r.examples, n);
                assert_eq!(r.rounds.len(), rounds);
            },
        ));
        // Wire cost of the same workload at this round count (one run,
        // deterministic): the communication-vs-rounds curve.
        let streams = partition_streams(&ds, 4, None);
        let r = run_fleet(
            fleet_cfg(4, rounds),
            storm_cfg,
            Topology::Star,
            ds.dim() + 1,
            3,
            streams,
        );
        json.record_scalar(&format!("fleet_net_bytes_4dev_{rounds}rounds"), r.network.bytes as f64);
        json.record_scalar(
            &format!("fleet_net_msgs_4dev_{rounds}rounds"),
            r.network.messages as f64,
        );
    }

    section("fleet: catch-up overhead vs drop rate (4 devices, star, 8 rounds)");
    // EXPERIMENTS.md §Resilience reads these scalars: at each controlled
    // drop rate, how many catch-up (retransmit) bytes the protocol
    // spends recovering the stream, as a fraction of total wire bytes.
    // The merged counters are asserted bit-identical to the loss-free
    // run — resilience costs bytes, never correctness.
    let baseline = {
        let streams = partition_streams(&ds, 4, None);
        run_fleet(fleet_cfg(4, 8), storm_cfg, Topology::Star, ds.dim() + 1, 3, streams)
    };
    for drop_per_mille in [0u16, 50, 100, 200, 400] {
        let plan = (drop_per_mille > 0).then(|| FaultPlan::drop_only(9, drop_per_mille));
        let streams = partition_streams(&ds, 4, None);
        let r = run_fleet_chaos(
            fleet_cfg(4, 8),
            storm_cfg,
            Topology::Star,
            ds.dim() + 1,
            3,
            streams,
            plan,
            |_, _| {},
        );
        assert_eq!(
            r.sketch.grid().counts_u32(),
            baseline.sketch.grid().counts_u32(),
            "drop rate {drop_per_mille} per-mille changed the counters"
        );
        json.record_scalar(
            &format!("fleet_chaos_net_bytes_drop{drop_per_mille}pm"),
            r.network.bytes as f64,
        );
        json.record_scalar(
            &format!("fleet_chaos_retransmit_bytes_drop{drop_per_mille}pm"),
            r.network.retransmit_bytes() as f64,
        );
        json.record_scalar(
            &format!("fleet_chaos_drops_drop{drop_per_mille}pm"),
            r.faults.drops as f64,
        );
    }

    section("merge experiment table");
    merge::run(Effort::from_env(), 5).print();

    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_fleet.json: {e}"),
    }
}
