//! Figure 3 regeneration bench: emits the fig3a/fig3b series and times
//! the surrogate-loss evaluation kernel.

use storm::experiments::fig3;
use storm::loss::prp_loss::prp_surrogate;
use storm::util::bench::{bench_items, black_box, config_from_env, section, JsonReporter};

fn main() {
    let mut json = JsonReporter::new("fig3");
    section("fig3a: surrogate loss vs t (closed form + sketch overlay)");
    fig3::run_fig3a(0).print();

    section("fig3b: slope at t=0.1 vs p");
    fig3::run_fig3b().print();

    section("loss evaluation kernel");
    let cfg = config_from_env();
    let ts: Vec<f64> = (0..1000).map(|i| -0.99 + 1.98 * i as f64 / 999.0).collect();
    for p in [2u32, 4, 16] {
        json.record(bench_items(&format!("prp_surrogate_1k_p{p}"), cfg, ts.len() as u64, || {
            for &t in &ts {
                black_box(prp_surrogate(t, p));
            }
        }));
    }

    json.record_peak_rss();
    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_fig3.json: {e}"),
    }
}
