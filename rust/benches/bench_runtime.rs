//! Runtime benchmarks: the AOT/XLA batched path vs the scalar rust path —
//! insert throughput and query (DFO probe) latency. These are the §Perf
//! headline numbers. Skips cleanly when `artifacts/` is missing.

use storm::config::StormConfig;
use storm::coordinator::oracle::XlaRiskOracle;
use storm::runtime::XlaStorm;
use storm::sketch::storm::StormSketch;
use storm::testing::gen_ball_point;
use storm::util::bench::{bench_items, black_box, config_from_env, section, JsonReporter};
use storm::util::rng::Xoshiro256;

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.toml").exists() {
        eprintln!("SKIP bench_runtime: artifacts/ missing — run `make artifacts`");
        return;
    }
    let cfg = config_from_env();
    let mut json = JsonReporter::new("runtime");
    // synth2d artifact config: D = 3, R = 100, p = 4.
    let scfg = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };
    let mut sk = StormSketch::new(scfg, 3, 7);
    let mut rng = Xoshiro256::new(1);
    let data: Vec<Vec<f64>> = (0..4096).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
    for z in &data {
        sk.insert(z);
    }
    let exe = XlaStorm::load(dir, 3, 100, 4, sk.hashes()).expect("load artifacts");

    section("insert: scalar rust vs XLA batched (batch=256)");
    let mut scratch = StormSketch::new(scfg, 3, 7);
    json.record(bench_items("insert_rust_scalar_4096", cfg, data.len() as u64, || {
        for z in &data {
            scratch.insert(z);
        }
    }));
    json.record(bench_items("insert_xla_batched_4096", cfg, data.len() as u64, || {
        for chunk in data.chunks(exe.batch_size()) {
            black_box(exe.insert_counts(chunk).unwrap());
        }
    }));

    section("query: scalar rust vs XLA batched (16 probes)");
    let queries: Vec<Vec<f64>> = (0..16)
        .map(|_| {
            let mut q = gen_ball_point(&mut rng, 2, 0.5);
            q.push(-1.0);
            q
        })
        .collect();
    json.record(bench_items("query_rust_scalar_x16", cfg, 16, || {
        for q in &queries {
            black_box(sk.estimate_risk_scaled(q));
        }
    }));
    let oracle = XlaRiskOracle::new(&exe, &sk);
    json.record(bench_items("query_xla_batched_x16", cfg, 16, || {
        black_box(oracle.risks(&queries));
    }));

    section("fused DFO step (1 XLA execution per iteration)");
    let mut theta = vec![0.0, 0.0, -1.0];
    let mut rng2 = Xoshiro256::new(9);
    json.record(bench_items("dfo_step_fused", cfg, 1, || {
        black_box(storm::coordinator::oracle::fused_dfo_step(
            &oracle, &mut theta, 8, 0.3, 0.6, &mut rng2,
        ));
    }));

    json.record_peak_rss();
    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_runtime.json: {e}"),
    }
}
