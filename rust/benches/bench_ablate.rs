//! Ablation bench: the design-choice comparison table (probe strategy,
//! iterate selection, scaling, hash power) — see experiments::ablate.

use storm::experiments::{ablate, Effort};
use storm::util::bench::section;

fn main() {
    section("ablate: design choices (variant ids in experiments::ablate)");
    ablate::run(Effort::from_env(), 0).print();
}
