//! Ablation bench: the design-choice comparison table (probe strategy,
//! iterate selection, scaling, hash power) — see experiments::ablate.

use storm::experiments::{ablate, Effort};
use storm::util::bench::{section, JsonReporter};

fn main() {
    section("ablate: design choices (variant ids in experiments::ablate)");
    ablate::run(Effort::from_env(), 0).print();

    let mut json = JsonReporter::new("ablate");
    json.record_peak_rss();
    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_ablate.json: {e}"),
    }
}
