//! CLI integration: drive the compiled `storm` binary end to end the way
//! a user would, asserting exit codes and output shape.

use std::process::Command;

fn storm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_storm"))
}

#[test]
fn help_and_usage() {
    let out = storm().arg("--help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("train") && text.contains("experiment"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = storm().arg("frobnicate").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn info_lists_datasets() {
    let out = storm().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["airfoil", "autos", "parkinsons"] {
        assert!(text.contains(name), "missing {name}");
    }
}

#[test]
fn experiment_list_and_cheap_run() {
    let out = storm().args(["experiment", "--list"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fig4") && text.contains("table1"));

    let out = storm().args(["experiment", "fig3b"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("# fig3b"));
    // The p=4 peak must appear in the series (column format "4.000000e0").
    assert!(text.contains("4.000000e0"));
}

#[test]
fn experiment_unknown_id_fails() {
    let out = storm().args(["experiment", "nope"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn sketch_subcommand_reports_compression() {
    let out = storm()
        .args(["sketch", "--dataset", "autos", "--rows", "50"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sketch R=50"));
    assert!(text.contains("compression"));
}

#[test]
fn train_small_run_with_checkpoint() {
    let ckpt = std::env::temp_dir().join("storm_cli_ckpt.txt");
    let _ = std::fs::remove_file(&ckpt);
    let out = storm()
        .args([
            "train",
            "--dataset",
            "synth2d-reg",
            "--rows",
            "100",
            "--iters",
            "50",
            "--devices",
            "2",
            "--checkpoint",
            ckpt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("storm-mse="));
    // Checkpoint parses back.
    let state = storm::coordinator::state::TrainingState::load(&ckpt).unwrap();
    assert_eq!(state.theta.len(), 2);
    assert_eq!(state.iter, 50);
}

#[test]
fn train_with_sync_rounds_prints_round_table() {
    let out = storm()
        .args([
            "train",
            "--dataset",
            "synth2d-reg",
            "--rows",
            "100",
            "--iters",
            "40",
            "--devices",
            "2",
            "--sync-rounds",
            "4",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rounds=4"), "summary missing round count: {text}");
    assert!(text.contains("round  examples  net_bytes  resend_bytes  est_risk"), "{text}");
    assert!(text.contains("memory: leader sketch"), "{text}");
    // One table line per round.
    assert!(text.contains("    0  ") && text.contains("    3  "), "{text}");
}

#[test]
fn train_with_privacy_and_decay_reports_the_ledger() {
    let out = storm()
        .args([
            "train",
            "--dataset",
            "synth2d-reg",
            "--rows",
            "100",
            "--iters",
            "40",
            "--devices",
            "2",
            "--sync-rounds",
            "4",
            "--epsilon",
            "0.5",
            "--decay-keep",
            "0.9",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("epsilon=2.000"), "summary missing the ledger: {text}");
    assert!(text.contains("privacy: epsilon 0.5 per round x 4 rounds = 2.000 total"), "{text}");
    assert!(text.contains("round  examples  net_bytes  resend_bytes  est_risk  eps_spent"), "{text}");
    assert!(text.contains("0.500") && text.contains("2.000"), "{text}");

    // Out-of-range knobs are rejected up front.
    let out = storm().args(["train", "--epsilon", "-1"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    let out = storm().args(["train", "--decay-keep", "0"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    let out = storm().args(["train", "--decay-keep", "1.5"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
}

#[test]
fn train_rejects_bad_dataset_and_backend() {
    let out = storm().args(["train", "--dataset", "nope"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    let out = storm()
        .args(["train", "--backend", "cuda"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
}

#[test]
fn train_with_narrow_device_counter_width() {
    let out = storm()
        .args([
            "train",
            "--dataset",
            "synth2d-reg",
            "--rows",
            "100",
            "--iters",
            "20",
            "--devices",
            "2",
            "--device-counter-width",
            "u8",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    // Leader at u32 (6400 B for 100 x 16), devices at u8 (1600 B).
    assert!(text.contains("leader sketch 6400 B (u32), per-device sketch 1600 B (u8)"), "{text}");

    // A bad width is rejected up front.
    let out = storm()
        .args(["train", "--counter-width", "u64"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
}

#[test]
fn config_files_parse() {
    // The checked-in configs must stay loadable.
    for f in ["configs/airfoil.toml", "configs/edge_fleet_xla.toml"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(f);
        let cfg = storm::config::RunConfig::from_toml_file(&path)
            .unwrap_or_else(|e| panic!("{f}: {e}"));
        assert_eq!(cfg.storm.rows, 1000);
        assert_eq!(cfg.fleet.devices, 8);
        assert_eq!(cfg.storm.task, storm::config::Task::Regression, "{f}: seed task default");
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs/classification_fleet.toml");
    let cfg = storm::config::RunConfig::from_toml_file(&path).expect("classification config");
    assert_eq!(cfg.storm.task, storm::config::Task::Classification);
    assert_eq!(cfg.dataset, "synth2d-clf");
    assert_eq!(cfg.storm.rows, 600);
    assert_eq!(cfg.fleet.sync_rounds, 3);
}

#[test]
fn train_classification_end_to_end_with_faults() {
    // The acceptance path: `storm train --task classification` over a
    // labelled synthetic stream, through the fleet, with faults
    // injected — must complete, report margin risk + accuracy, and
    // account the chaos.
    let out = storm()
        .args([
            "train",
            "--task",
            "classification",
            "--dataset",
            "synth2d-clf",
            "--rows",
            "200",
            "--power",
            "2",
            "--iters",
            "60",
            "--devices",
            "3",
            "--sync-rounds",
            "3",
            "--faults-seed",
            "7",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("margin-risk="), "{text}");
    assert!(text.contains("acc="), "{text}");
    assert!(text.contains("classification: training accuracy"), "{text}");
    assert!(text.contains("chaos:"), "faults must be injected and reported: {text}");
    assert!(text.contains("round  examples  net_bytes  resend_bytes  est_risk"), "{text}");
}

#[test]
fn train_rejects_bad_task_and_xla_classification() {
    let out = storm()
        .args(["train", "--task", "ranking"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let out = storm()
        .args([
            "train",
            "--task",
            "classification",
            "--dataset",
            "synth2d-clf",
            "--backend",
            "xla",
        ])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("regression only"));
}
