//! Integration: the AOT-compiled XLA artifacts against the pure-rust
//! scalar path. This is the cross-language parity suite — both backends
//! share the same hyperplanes (runtime inputs), so their counters and
//! risk estimates must agree bit-for-bit (counts) / to f32 rounding
//! (risks).
//!
//! Requires `make artifacts`; every test skips with a notice if the
//! artifact directory is missing so `cargo test` works standalone.

use storm::config::StormConfig;
use storm::coordinator::oracle::XlaRiskOracle;
use storm::optim::RiskOracle;
use storm::runtime::XlaStorm;
use storm::sketch::storm::StormSketch;
use storm::testing::gen_ball_point;
use storm::util::rng::Xoshiro256;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn artifacts_available() -> bool {
    std::path::Path::new(ARTIFACTS).join("manifest.toml").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

/// Build a filled sketch in the synth2d configuration (D = 3, R = 100,
/// p = 4 — matches the compiled `synth2d` artifact pair).
fn filled_sketch(n: usize, seed: u64) -> (StormSketch, Vec<Vec<f64>>) {
    let cfg = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };
    let mut sk = StormSketch::new(cfg, 3, seed);
    let mut rng = Xoshiro256::new(seed ^ 0xDEAD);
    let data: Vec<Vec<f64>> = (0..n).map(|_| gen_ball_point(&mut rng, 3, 0.9)).collect();
    for z in &data {
        sk.insert(z);
    }
    (sk, data)
}

fn load_exe(sk: &StormSketch) -> XlaStorm {
    XlaStorm::load(ARTIFACTS, 3, 100, 4, sk.hashes()).expect("load synth2d artifacts")
}

#[test]
fn insert_counts_match_rust_exactly() {
    require_artifacts!();
    let (sk, data) = filled_sketch(200, 11);
    let exe = load_exe(&sk);
    // Feed the same examples through the XLA insert kernel in batches.
    let mut total = vec![0u64; sk.grid().counts_u32().len()];
    for chunk in data.chunks(exe.batch_size()) {
        let delta = exe.insert_counts(chunk).expect("insert execute");
        for (t, d) in total.iter_mut().zip(&delta) {
            *t += *d as u64;
        }
    }
    let rust_counts: Vec<u64> = sk.grid().counts_u32().iter().map(|&c| c as u64).collect();
    assert_eq!(total, rust_counts, "XLA and rust counters diverged");
}

#[test]
fn short_batch_padding_contributes_nothing() {
    require_artifacts!();
    let (sk, _) = filled_sketch(1, 13);
    let exe = load_exe(&sk);
    let mut rng = Xoshiro256::new(5);
    let z = gen_ball_point(&mut rng, 3, 0.8);
    // Single example in a padded batch.
    let delta = exe.insert_counts(std::slice::from_ref(&z)).unwrap();
    let total: u64 = delta.iter().map(|&c| c as u64).sum();
    // Exactly 2 increments per row, R = 100.
    assert_eq!(total, 200);
}

#[test]
fn empty_batch_is_all_zero() {
    require_artifacts!();
    let (sk, _) = filled_sketch(1, 17);
    let exe = load_exe(&sk);
    let delta = exe.insert_counts(&[]).unwrap();
    assert!(delta.iter().all(|&c| c == 0));
}

#[test]
fn query_risks_match_rust_estimates() {
    require_artifacts!();
    let (sk, _) = filled_sketch(300, 19);
    let exe = load_exe(&sk);
    let mut rng = Xoshiro256::new(7);
    let queries: Vec<Vec<f64>> = (0..10).map(|_| gen_ball_point(&mut rng, 3, 0.85)).collect();
    let got = exe
        .query_risks(&sk.grid().counts_u32(), sk.count(), &queries)
        .expect("query execute");
    for (q, g) in queries.iter().zip(&got) {
        let want = sk.estimate_risk(q);
        assert!(
            (g - want).abs() < 1e-5,
            "query mismatch: xla={g} rust={want} q={q:?}"
        );
    }
}

#[test]
fn xla_oracle_agrees_with_sketch_oracle() {
    require_artifacts!();
    let (sk, _) = filled_sketch(400, 23);
    let exe = load_exe(&sk);
    let oracle = XlaRiskOracle::new(&exe, &sk);
    let mut rng = Xoshiro256::new(9);
    for _ in 0..5 {
        // Out-of-ball theta~: both paths must rescale identically.
        let mut tt = gen_ball_point(&mut rng, 2, 1.5);
        tt.push(-1.0);
        let want = sk.risk(&tt);
        let got = oracle.risk(&tt);
        assert!(
            (got - want).abs() < 1e-5,
            "oracle mismatch: xla={got} rust={want}"
        );
    }
    assert!(oracle.last_error().is_none());
    assert_eq!(oracle.evals(), 5);
}

#[test]
fn batched_probes_use_one_execution() {
    require_artifacts!();
    let (sk, _) = filled_sketch(100, 29);
    let exe = load_exe(&sk);
    let oracle = XlaRiskOracle::new(&exe, &sk);
    let mut rng = Xoshiro256::new(11);
    let candidates: Vec<Vec<f64>> = (0..16)
        .map(|_| {
            let mut t = gen_ball_point(&mut rng, 2, 0.5);
            t.push(-1.0);
            t
        })
        .collect();
    let before = oracle.executions();
    let risks = oracle.risks(&candidates);
    assert_eq!(risks.len(), 16);
    // Compiled K = 16 — exactly one execution for 16 probes.
    assert_eq!(oracle.executions() - before, 1);
}

#[test]
fn fused_dfo_step_reduces_risk_on_average() {
    require_artifacts!();
    use storm::coordinator::oracle::fused_dfo_step;
    let (sk, _) = filled_sketch(500, 31);
    let exe = load_exe(&sk);
    let oracle = XlaRiskOracle::new(&exe, &sk);
    let mut theta_tilde = vec![0.0, 0.0, -1.0];
    let mut rng = Xoshiro256::new(13);
    let first = fused_dfo_step(&oracle, &mut theta_tilde, 8, 0.3, 0.6, &mut rng);
    let mut last = first;
    for _ in 0..60 {
        last = fused_dfo_step(&oracle, &mut theta_tilde, 8, 0.3, 0.6, &mut rng);
    }
    assert!(last.is_finite());
    assert_eq!(theta_tilde[2], -1.0);
    // The trajectory must have moved.
    assert!(theta_tilde[0].abs() + theta_tilde[1].abs() > 1e-6);
}

#[test]
fn bulk_ingest_matches_scalar_path() {
    require_artifacts!();
    use storm::coordinator::ingest::xla_bulk_ingest;
    use storm::data::dataset::Dataset;
    use storm::data::stream::ReplayStream;
    use storm::linalg::matrix::Matrix;
    // A 2-feature dataset whose augmented dim D = 3 matches the synth2d
    // artifact pair.
    let mut rng = Xoshiro256::new(41);
    let n = 700;
    let x = Matrix::from_fn(n, 2, |r, c| {
        let _ = (r, c);
        0.0
    });
    let mut ds = Dataset::new("bulk", x, vec![0.0; n]);
    for i in 0..n {
        let p = gen_ball_point(&mut rng, 3, 0.9);
        ds.x.row_mut(i).copy_from_slice(&p[..2]);
        ds.y[i] = p[2];
    }
    let cfg = StormConfig { rows: 100, power: 4, saturating: true, ..Default::default() };
    // Scalar reference.
    let mut scalar = StormSketch::new(cfg, 3, 47);
    for i in 0..ds.len() {
        scalar.insert(&ds.augmented(i));
    }
    // XLA bulk path.
    let mut bulk = StormSketch::new(cfg, 3, 47);
    let exe = XlaStorm::load(ARTIFACTS, 3, 100, 4, bulk.hashes()).unwrap();
    let mut stream = ReplayStream::new(ds);
    let report = xla_bulk_ingest(&mut stream, &exe, &mut bulk).unwrap();
    assert_eq!(report.examples, n as u64);
    assert_eq!(report.batches, (n as u64).div_ceil(exe.batch_size() as u64));
    assert_eq!(bulk.count(), scalar.count());
    assert_eq!(
        bulk.grid().counts_u32(),
        scalar.grid().counts_u32(),
        "bulk-ingest counters diverged from scalar path"
    );
}

#[test]
fn wrong_config_is_a_clean_error() {
    require_artifacts!();
    let cfg = StormConfig { rows: 33, power: 4, saturating: true, ..Default::default() };
    let sk = StormSketch::new(cfg, 3, 1);
    let err = XlaStorm::load(ARTIFACTS, 3, 33, 4, sk.hashes());
    assert!(err.is_err(), "rows=33 is not compiled; load must fail");
}
