//! Property-based invariant tests over randomized configurations (the
//! crate's seeded case-sweep framework stands in for proptest, which is
//! not in the offline vendor set).
//!
//! Every sweep here honours the framework's environment knobs
//! (`storm::testing`): `STORM_TEST_CASES=<m>` multiplies each case
//! budget (the scheduled deep CI job runs at 10x), and
//! `STORM_TEST_REPLAY=<seed>:<case>` re-runs exactly one failing case
//! with its exact RNG stream — the value is printed by any failure.

use storm::config::{CounterWidth, FleetConfig, HashFamily, StormConfig, Task};
use storm::data::stream::partition_streams;
use storm::edge::faults::FaultPlan;
use storm::edge::fleet::{run_fleet_model, run_fleet_model_chaos};
use storm::edge::topology::Topology;
use storm::lsh::asym::{augment, Side};
use storm::lsh::prp::PairedRandomProjection;
use storm::lsh::srp::SignedRandomProjection;
use storm::lsh::LshFunction;
use storm::sketch::model::StormModel;
use storm::sketch::serialize::{
    decode, decode_delta, encode, encode_delta, encode_delta_v3, wire_bytes,
};
use storm::sketch::storm::{StormClassifierSketch, StormSketch};
use storm::sketch::RiskSketch;
use storm::testing::{
    assert_close, cases, gen_ball_point, gen_dim, test_counter_width, test_hash_family,
    test_privacy_epsilon, test_task,
};
use storm::util::mathx::{dot, norm2};
use storm::util::rng::Rng;

#[test]
fn prop_srp_hash_in_range_any_dim_and_power() {
    cases(200, 101, |rng, case| {
        let dim = gen_dim(rng, 1, 40);
        let p = 1 + (case % 12) as u32;
        let h = SignedRandomProjection::new(dim, p, case as u64);
        let x: Vec<f64> = (0..dim).map(|_| rng.uniform_range(-5.0, 5.0)).collect();
        assert!(h.hash(&x) < h.range());
    });
}

#[test]
fn prop_augmentation_unit_norm_and_ip_preserving() {
    cases(200, 102, |rng, _| {
        let dim = gen_dim(rng, 1, 30);
        let a = gen_ball_point(rng, dim, 0.999);
        let b = gen_ball_point(rng, dim, 0.999);
        let aq = augment(&a, Side::Query);
        let ab = augment(&b, Side::Data);
        assert_close(norm2(&aq), 1.0, 1e-9);
        assert_close(norm2(&ab), 1.0, 1e-9);
        assert_close(dot(&aq, &ab), dot(&a, &b), 1e-9);
    });
}

#[test]
fn prop_sketch_row_mass_is_2n() {
    // Invariant: every row's counters sum to exactly 2 * inserts.
    cases(60, 103, |rng, case| {
        let dim = gen_dim(rng, 1, 12);
        let rows = 1 + (case % 20);
        let p = 1 + (case % 6) as u32;
        let cfg = StormConfig {
            rows,
            power: p,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        let n = 1 + (rng.next_u64() % 60) as usize;
        for _ in 0..n {
            sk.insert(&gen_ball_point(rng, dim, 0.95));
        }
        for r in 0..rows {
            let mass: u64 = sk.grid().row(r).iter().map(|&c| c as u64).sum();
            assert_eq!(mass, 2 * n as u64);
        }
        assert_eq!(sk.count(), n as u64);
    });
}

#[test]
fn prop_merge_commutative_and_associative() {
    cases(40, 104, |rng, case| {
        let cfg = StormConfig {
            rows: 8,
            power: 3,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let dim = gen_dim(rng, 1, 8);
        let seed = case as u64;
        let mut mk = |rng: &mut storm::util::rng::Xoshiro256, n: usize| {
            let mut s = StormSketch::new(cfg, dim, seed);
            for _ in 0..n {
                s.insert(&gen_ball_point(rng, dim, 0.9));
            }
            s
        };
        let a = mk(rng, 10);
        let b = mk(rng, 15);
        let c = mk(rng, 7);
        // (a + b) + c == a + (b + c), and a + b == b + a.
        let mut ab = StormSketch::new(cfg, dim, seed);
        ab.merge_from(&a);
        ab.merge_from(&b);
        let mut ba = StormSketch::new(cfg, dim, seed);
        ba.merge_from(&b);
        ba.merge_from(&a);
        assert_eq!(ab.grid().counts_u32(), ba.grid().counts_u32());
        let mut abc1 = ab;
        abc1.merge_from(&c);
        let mut bc = StormSketch::new(cfg, dim, seed);
        bc.merge_from(&b);
        bc.merge_from(&c);
        let mut abc2 = StormSketch::new(cfg, dim, seed);
        abc2.merge_from(&a);
        abc2.merge_from(&bc);
        assert_eq!(abc1.grid().counts_u32(), abc2.grid().counts_u32());
        assert_eq!(abc1.count(), 32);
    });
}

#[test]
fn prop_wire_roundtrip_any_config() {
    cases(60, 105, |rng, case| {
        let rows = 1 + (case % 30);
        let p = 1 + (case % 8) as u32;
        let dim = gen_dim(rng, 1, 16);
        let cfg = StormConfig {
            rows,
            power: p,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let mut sk = StormSketch::new(cfg, dim, case as u64 ^ 0xABCD);
        let n = (rng.next_u64() % 40) as usize;
        for _ in 0..n {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let back = decode(&encode(&sk)).unwrap();
        assert_eq!(back.grid().counts_u32(), sk.grid().counts_u32());
        assert_eq!(back.count(), sk.count());
        assert_eq!(back.dim(), sk.dim());
    });
}

#[test]
fn prop_delta_wire_roundtrip_any_config() {
    // Snapshot mid-stream, ship the tail as an epoch-tagged v2 delta,
    // decode, apply onto a replica of the snapshot state: the replica
    // must equal the live sketch bit-for-bit. Exercises both the sparse
    // and (for tiny dense grids) the fallback encoding.
    cases(60, 113, |rng, case| {
        let rows = 1 + (case % 25);
        let p = 1 + (case % 6) as u32;
        let dim = gen_dim(rng, 1, 12);
        let cfg = StormConfig {
            rows,
            power: p,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let seed = case as u64 ^ 0xDE17A;
        let mut sk = StormSketch::new(cfg, dim, seed);
        let head = (rng.next_u64() % 30) as usize;
        for _ in 0..head {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let snap = sk.snapshot();
        // Replica of the snapshot-time state, to apply the delta onto.
        let mut replica = StormSketch::new(cfg, dim, seed);
        replica.merge_from(&sk);
        let tail = (rng.next_u64() % 40) as usize;
        for _ in 0..tail {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let epoch = rng.next_u64() % 1000;
        let delta = sk.delta_since(&snap, epoch);
        assert_eq!(delta.count, tail as u64);
        let back = decode_delta(&encode_delta(&delta)).unwrap();
        assert_eq!(back, delta, "rows={rows} p={p} dim={dim}");
        replica.apply_delta(&back);
        assert_eq!(replica.grid().counts_u32(), sk.grid().counts_u32());
        assert_eq!(replica.count(), sk.count());
    });
}

#[test]
fn prop_sparse_delta_cheaper_than_dense_v1() {
    // Acceptance: a sparse round's v2 frame must cost strictly fewer
    // bytes than a dense v1 encode of the full sketch. A round touching
    // few cells (few inserts into a roomy grid) is the sparse regime.
    cases(40, 114, |rng, case| {
        let rows = 8 + (case % 40);
        let cfg = StormConfig {
            rows,
            power: 4,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let dim = gen_dim(rng, 1, 10);
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        let snap = sk.snapshot();
        let n = 1 + (rng.next_u64() % 3) as usize;
        for _ in 0..n {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let delta = sk.delta_since(&snap, 0);
        assert!(delta.populated_fraction() <= 0.5, "not sparse: {}", delta.populated_fraction());
        let sparse_len = encode_delta(&delta).len();
        assert!(
            sparse_len < wire_bytes(&cfg),
            "sparse {} >= dense {} (rows={rows})",
            sparse_len,
            wire_bytes(&cfg)
        );
    });
}

#[test]
fn prop_wire_corruption_errors_never_panic() {
    // Satellite contract: random truncations and byte flips of ALL wire
    // versions (v1 dense, v2 delta, width-tagged v3 deltas at every
    // width) always yield a WireError — no panic, no silent success.
    cases(80, 115, |rng, case| {
        let width = [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32][case % 3];
        let cfg = StormConfig {
            rows: 1 + (case % 12),
            power: 1 + (case % 5) as u32,
            saturating: true,
            counter_width: width,
            ..Default::default()
        };
        let dim = gen_dim(rng, 1, 8);
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        let snap = sk.snapshot();
        for _ in 0..(rng.next_u64() % 25) {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let delta = sk.delta_since(&snap, case as u64);
        let frames = [encode(&sk), encode_delta(&delta), encode_delta_v3(&delta)];
        for bytes in &frames {
            // Random truncation (strictly shorter, including empty).
            let cut = (rng.next_u64() % bytes.len() as u64) as usize;
            assert!(decode_delta(&bytes[..cut]).is_err(), "truncation to {cut} accepted");
            assert!(decode(&bytes[..cut]).is_err());
            // Random single-byte flip: FNV-1a over the body is injective
            // in any one byte, so every flip must trip the checksum (or a
            // validation that fires before it).
            let mut flipped = bytes.clone();
            let at = (rng.next_u64() % flipped.len() as u64) as usize;
            let bit = 1u8 << (rng.next_u64() % 8);
            flipped[at] ^= bit;
            assert!(decode_delta(&flipped).is_err(), "flip at {at} accepted");
        }
    });
}

#[test]
fn prop_header_mutations_with_valid_crc_rejected() {
    // Structural header lies must be caught by validation even when the
    // attacker (or a memory error) recomputes a valid checksum.
    fn fnv1a(bytes: &[u8]) -> u32 {
        // Mirror of the (private) serializer checksum, for re-fixing.
        let mut h: u32 = 0x811c9dc5;
        for &b in bytes {
            h ^= b as u32;
            h = h.wrapping_mul(0x01000193);
        }
        h
    }
    fn refix(bytes: &mut [u8]) {
        let n = bytes.len();
        let crc = fnv1a(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
    }
    cases(40, 116, |rng, case| {
        let cfg = StormConfig {
            rows: 2 + (case % 10),
            power: 1 + (case % 4) as u32,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let dim = gen_dim(rng, 1, 6);
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        let snap = sk.snapshot();
        for _ in 0..(1 + rng.next_u64() % 10) {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let frames = [encode(&sk), encode_delta(&sk.delta_since(&snap, 1))];
        for bytes in &frames {
            // (offset range, lie) tuples: magic, version, power, rows, and
            // a payload-length lie (drop the last payload byte).
            let mutations: [&dyn Fn(&mut Vec<u8>); 5] = [
                &|b: &mut Vec<u8>| b[0] ^= 0xFF,                                  // magic
                &|b: &mut Vec<u8>| b[4..6].copy_from_slice(&9u16.to_le_bytes()),  // version
                &|b: &mut Vec<u8>| b[6..8].copy_from_slice(&0u16.to_le_bytes()),  // power 0
                &|b: &mut Vec<u8>| b[8..12].copy_from_slice(&0u32.to_le_bytes()), // rows 0
                &|b: &mut Vec<u8>| {
                    let n = b.len();
                    b.remove(n - 5); // shrink payload by one byte
                },
            ];
            for (i, m) in mutations.iter().enumerate() {
                let mut lying = bytes.clone();
                m(&mut lying);
                refix(&mut lying);
                assert!(decode_delta(&lying).is_err(), "mutation {i} accepted");
            }
        }
    });
}

#[test]
fn prop_round_sync_bit_identical_to_oneshot() {
    // THE tentpole invariant: for a fixed family seed, R rounds of delta
    // synchronization produce a leader model bit-identical to the
    // one-shot full merge — across device counts and topologies, for
    // whichever task STORM_TEST_TASK selects (the CI matrix runs the
    // sweep once per task).
    let task = test_task();
    cases(8, 117, |rng, case| {
        let n_examples = 60 + (rng.next_u64() % 120) as usize;
        let devices = 1 + (case % 4);
        let rounds = 1 + (case % 5);
        let topo = if case % 2 == 0 { Topology::Star } else { Topology::Tree { fanout: 2 } };
        let storm = StormConfig {
            rows: 6 + (case % 10),
            power: 3,
            saturating: true,
            counter_width: test_counter_width(),
            task,
            hash_family: test_hash_family(),
        };
        let ds = task_ds(n_examples, case as u64, task);
        let family_seed = 0xF1EE7 ^ case as u64;
        // One-shot reference: a single local model over the whole set.
        let reference = reference_model(storm, &ds, family_seed);
        let fleet = FleetConfig {
            devices,
            batch: 16,
            channel_capacity: 2,
            link_latency_us: 0,
            link_bandwidth_bps: 0,
            sync_rounds: rounds,
            min_quorum: 0,
            faults_seed: None,
            device_counter_width: None,
            // Rotate executor pool sizes: the schedule must never show
            // in the counters.
            workers: 1 + case % 3,
            fan_in: 2,
            epsilon_per_round: 0.0,
            decay_keep_permille: 1000,
            seed: 0,
        };
        let streams = partition_streams(&ds, devices, None);
        let result =
            run_fleet_model::<StormModel>(fleet, storm, topo, ds.dim() + 1, family_seed, streams);
        assert_eq!(result.sketch.task(), task);
        assert_eq!(
            result.sketch.grid().counts_u32(),
            reference.grid().counts_u32(),
            "devices={devices} rounds={rounds} topo={topo:?} task={task}"
        );
        assert_eq!(result.sketch.count(), reference.count());
        assert_eq!(result.rounds.len(), rounds);
        assert_eq!(result.examples, n_examples as u64);
        // No faults configured: zero injected events, zero catch-up
        // traffic — the PR-2 ideal-network behaviour, bit for bit.
        assert_eq!(result.faults.total(), 0);
        assert_eq!(result.network.retransmit_bytes(), 0);
    });
}

#[test]
fn prop_chaotic_sync_bit_identical_to_fault_free_oneshot() {
    // THE resilience invariant: for ANY seeded fault schedule with
    // eventual delivery — drops (recovered as multi-epoch catch-up
    // deltas), duplicates (deduplicated by `(from, epoch)`),
    // reordering/delay, straggler rounds, and one device crash/restart —
    // the final merged counters are bit-identical to the fault-free
    // one-shot merge, across star/tree/chain topologies and barrier
    // quorums. Replay a failing case with
    // STORM_TEST_REPLAY=118:<case>; the fault schedule itself is a pure
    // function of the printed faults_seed.
    let task = test_task();
    let mut injected_total = 0u64;
    let ran = cases(9, 118, |rng, case| {
        let n_examples = 80 + (rng.next_u64() % 140) as usize;
        let devices = 2 + (case % 4);
        let rounds = 2 + (case % 5);
        let topo = match case % 4 {
            0 => Topology::Star,
            1 => Topology::Tree { fanout: 2 },
            2 => Topology::Deep { max_fan_in: 3 },
            _ => Topology::Chain,
        };
        let storm = StormConfig {
            rows: 6 + (case % 8),
            power: 3,
            saturating: true,
            counter_width: test_counter_width(),
            task,
            hash_family: test_hash_family(),
        };
        let ds = task_ds(n_examples, case as u64 ^ 0xFA, task);
        let family_seed = 0xFA17 ^ case as u64;
        // One-shot fault-free reference: a single local model.
        let reference = reference_model(storm, &ds, family_seed);
        let faults_seed = rng.next_u64();
        let plan = FaultPlan::from_seed(faults_seed);
        let fleet = FleetConfig {
            devices,
            batch: 16,
            channel_capacity: 2,
            link_latency_us: 0,
            link_bandwidth_bps: 0,
            sync_rounds: rounds,
            // Alternate full and partial barrier quorums.
            min_quorum: if case % 2 == 0 { 0 } else { 1 + case % devices },
            faults_seed: None,
            device_counter_width: None,
            // The headline invariant must hold through the arena
            // executor at every pool size — including pools larger
            // than the fleet.
            workers: [1, 2, 8][case % 3],
            fan_in: 2,
            epsilon_per_round: 0.0,
            decay_keep_permille: 1000,
            seed: 0,
        };
        let streams = partition_streams(&ds, devices, None);
        let result = run_fleet_model_chaos::<StormModel, _>(
            fleet,
            storm,
            topo,
            ds.dim() + 1,
            family_seed,
            streams,
            Some(plan),
            |_, _| {},
        );
        let ctx = format!(
            "faults_seed={faults_seed:#x} devices={devices} rounds={rounds} topo={topo:?} task={task}"
        );
        assert_eq!(result.sketch.grid().counts_u32(), reference.grid().counts_u32(), "{ctx}");
        assert_eq!(result.sketch.count(), reference.count(), "{ctx}");
        assert_eq!(result.examples, n_examples as u64, "{ctx}");
        assert_eq!(result.rounds.len(), rounds, "every round closes: {ctx}");
        // The leader's anytime trace stays monotone no matter how
        // messily deltas arrive.
        let counts: Vec<u64> = result.rounds.iter().map(|r| r.leader_count).collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?} ({ctx})");
        injected_total += result.faults.total();
    });
    if ran > 0 {
        assert!(injected_total > 0, "chaos sweep injected no faults at all — vacuous");
    }
}

#[test]
fn prop_widening_merge_exact_without_saturation() {
    // THE width invariant: for any stream where no device cell saturates,
    // a fleet whose devices sketch at ANY width, folding into a leader at
    // least as wide, produces counters equal — counter-for-counter — to
    // the all-u32 merge, across star/tree/chain topologies, round counts
    // and all width pairs. The stream is capped at 120 examples: each
    // insert adds 2 increments per row, so no cell anywhere (device or
    // leader) can reach even the u8 clip of 255 — exactness is forced by
    // the hypothesis, not by luck.
    let widths = [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32];
    let pairs: Vec<(CounterWidth, CounterWidth)> = widths
        .iter()
        .flat_map(|&d| widths.iter().filter(move |&&l| l >= d).map(move |&l| (d, l)))
        .collect();
    cases(12, 119, |rng, case| {
        let (device_w, leader_w) = pairs[case % pairs.len()];
        let devices = 2 + (case % 3);
        let rounds = 1 + (case % 3);
        let topo = match case % 3 {
            0 => Topology::Star,
            1 => Topology::Tree { fanout: 2 },
            _ => Topology::Chain,
        };
        let n_examples = 40 + (rng.next_u64() % 80) as usize; // <= 120
        let task = test_task();
        let storm_u32 = StormConfig {
            rows: 6 + (case % 6),
            power: 3,
            saturating: true,
            counter_width: CounterWidth::U32,
            task,
            hash_family: test_hash_family(),
        };
        let ds = task_ds(n_examples, case as u64 ^ 0x71D7, task);
        let family_seed = 0x71D7 ^ case as u64;
        // All-u32 one-shot reference over the whole stream.
        let reference = reference_model(storm_u32, &ds, family_seed);
        let fleet = FleetConfig {
            devices,
            batch: 16,
            channel_capacity: 2,
            link_latency_us: 0,
            link_bandwidth_bps: 0,
            sync_rounds: rounds,
            min_quorum: 0,
            faults_seed: None,
            device_counter_width: Some(device_w),
            // Widening merges must stay exact at every pool size.
            workers: [1, 2, 8][case % 3],
            fan_in: 2,
            epsilon_per_round: 0.0,
            decay_keep_permille: 1000,
            seed: 0,
        };
        let leader_storm = StormConfig { counter_width: leader_w, ..storm_u32 };
        let streams = partition_streams(&ds, devices, None);
        let result = run_fleet_model::<StormModel>(
            fleet, leader_storm, topo, ds.dim() + 1, family_seed, streams,
        );
        let ctx =
            format!("device={device_w} leader={leader_w} devices={devices} topo={topo:?} task={task}");
        assert_eq!(result.sketch.grid().width(), leader_w, "{ctx}");
        assert_eq!(
            result.sketch.grid().counts_u32(),
            reference.grid().counts_u32(),
            "widened fleet merge must equal the all-u32 merge: {ctx}"
        );
        assert_eq!(result.sketch.count(), reference.count(), "{ctx}");
        // Hypothesis check: nothing came close to the u8 clip.
        assert!(
            reference.grid().counts_u32().iter().all(|&c| c < u8::MAX as u32),
            "stream cap failed to prevent saturation: {ctx}"
        );
        // Per-device memory is width-true.
        for d in &result.devices {
            assert_eq!(
                d.sketch_bytes,
                storm_u32.rows * storm_u32.buckets() * device_w.bytes(),
                "{ctx}"
            );
        }
    });
}

#[test]
fn prop_u8_saturation_graceful() {
    // Satellite: a u8 cell driven past 255 degrades gracefully — it
    // clips at exactly `min(exact, 255)` (never wraps), neighbouring
    // cells stay exact, and the snapshot/delta pipeline stays
    // self-consistent (a replica fed only the deltas reproduces the
    // saturated grid bit-for-bit).
    use storm::sketch::counters::CounterGrid;
    cases(40, 120, |rng, case| {
        let buckets = 4 + (case % 8);
        let cells = 2 * buckets;
        let mut narrow = CounterGrid::with_width(2, buckets, true, CounterWidth::U8);
        let mut wide = CounterGrid::new(2, buckets, true);
        let mut replica = CounterGrid::with_width(2, buckets, true, CounterWidth::U8);
        for _ in 0..4 {
            let mut volley: Vec<u32> = (0..cells)
                .map(|_| match rng.next_u64() % 4 {
                    0 => 0,
                    1 | 2 => (rng.next_u64() % 100) as u32,
                    _ => 100 + (rng.next_u64() % 200) as u32,
                })
                .collect();
            volley[0] = 200; // cell 0 provably saturates by volley two
            let snap = narrow.snapshot();
            narrow.add_counts(&volley);
            wide.add_counts(&volley);
            replica.apply_delta(&narrow.delta_since(&snap));
        }
        let exact = wide.counts_u32();
        let clipped = narrow.counts_u32();
        for (i, (&e, &c)) in exact.iter().zip(&clipped).enumerate() {
            assert_eq!(c, e.min(u8::MAX as u32), "cell {i}: clip must be exact-min, not a wrap");
        }
        assert_eq!(clipped[0], 255, "saturation case was vacuous");
        // Deltas never corrupt: the replica that saw only per-volley
        // deltas equals the live saturated grid.
        assert_eq!(replica.counts_u32(), clipped);
        assert_eq!(replica, narrow);
    });
}

/// Small random regression dataset for the fleet property tests.
fn storm_ds(n: usize, seed: u64) -> storm::data::dataset::Dataset {
    let mut rng = storm::util::rng::Xoshiro256::new(seed ^ 0xD5);
    let d = 3;
    let x = storm::linalg::matrix::Matrix::from_fn(n, d, |_, _| rng.uniform_range(-1.0, 1.0));
    let y: Vec<f64> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
    storm::data::dataset::Dataset::new("prop-fleet", x, y)
}

/// Task-appropriate dataset for the fleet property sweeps: regression
/// gets the unit-ball-scaled random stream; classification scales
/// features only and plants exact ±1 labels (the margin hash folds them
/// into the sign, so they must stay exact).
fn task_ds(n: usize, seed: u64, task: Task) -> storm::data::dataset::Dataset {
    let mut ds = storm_ds(n, seed);
    match task {
        Task::Regression => {
            storm::data::scale::scale_to_unit_ball(&mut ds, 0.9);
        }
        Task::Classification => {
            storm::data::scale::scale_features_to_unit_ball(&mut ds, 0.9);
            for (i, y) in ds.y.iter_mut().enumerate() {
                *y = if i % 2 == 0 { 1.0 } else { -1.0 };
            }
        }
    }
    ds
}

/// One-shot local model over the whole dataset (the fleet reference).
fn reference_model(
    storm: StormConfig,
    ds: &storm::data::dataset::Dataset,
    family_seed: u64,
) -> StormModel {
    let mut reference = StormModel::new(storm, ds.dim() + 1, family_seed);
    for i in 0..ds.len() {
        reference.insert(&ds.augmented(i));
    }
    reference
}

#[test]
fn prop_classifier_merge_equals_concatenation_all_widths_and_topologies() {
    // Classifier parity satellite: merge-equals-concatenation for the
    // margin-hash sketch at every counter width, both directly
    // (merge_from of split streams) and through the real fleet across
    // star/tree/chain.
    let widths = [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32];
    cases(9, 121, |rng, case| {
        let width = widths[case % widths.len()];
        let topo = match case % 3 {
            0 => Topology::Star,
            1 => Topology::Tree { fanout: 2 },
            _ => Topology::Chain,
        };
        let devices = 2 + (case % 3);
        let rounds = 1 + (case % 3);
        let n_examples = 40 + (rng.next_u64() % 80) as usize; // u8-safe: 1 inc/row/example
        let storm = StormConfig {
            rows: 5 + (case % 7),
            power: 2,
            saturating: true,
            counter_width: width,
            task: Task::Classification,
            hash_family: test_hash_family(),
        };
        let ds = task_ds(n_examples, case as u64 ^ 0xC1F, Task::Classification);
        let family_seed = 0xC1F0 ^ case as u64;
        let reference = reference_model(storm, &ds, family_seed);

        // Direct merge: split the stream at a random point.
        let cut = 1 + (rng.next_u64() as usize % (n_examples - 1));
        let mut a = StormClassifierSketch::new(storm, ds.dim(), family_seed);
        let mut b = StormClassifierSketch::new(storm, ds.dim(), family_seed);
        for i in 0..ds.len() {
            let z = ds.augmented(i);
            if i < cut {
                a.insert_labelled(&z[..ds.dim()], z[ds.dim()]);
            } else {
                b.insert_labelled(&z[..ds.dim()], z[ds.dim()]);
            }
        }
        a.merge_from(&b);
        assert_eq!(
            a.grid().counts_u32(),
            reference.grid().counts_u32(),
            "direct merge: width={width} cut={cut}"
        );
        assert_eq!(a.count(), n_examples as u64);

        // Fleet merge: same invariant through devices + aggregators.
        let fleet = FleetConfig {
            devices,
            batch: 16,
            channel_capacity: 2,
            link_latency_us: 0,
            link_bandwidth_bps: 0,
            sync_rounds: rounds,
            min_quorum: 0,
            faults_seed: None,
            device_counter_width: None,
            workers: 1 + case % 2,
            fan_in: 2,
            epsilon_per_round: 0.0,
            decay_keep_permille: 1000,
            seed: 0,
        };
        let streams = partition_streams(&ds, devices, None);
        let result =
            run_fleet_model::<StormModel>(fleet, storm, topo, ds.dim() + 1, family_seed, streams);
        assert_eq!(
            result.sketch.grid().counts_u32(),
            reference.grid().counts_u32(),
            "fleet merge: width={width} topo={topo:?} rounds={rounds}"
        );
        assert_eq!(result.sketch.count(), n_examples as u64);
        // Row mass sanity: the single-arm hash adds exactly ONE count
        // per row per example (vs two for the paired regression hash).
        for r in 0..storm.rows {
            let mass: u64 = result.sketch.grid().row(r).iter().map(|&c| c as u64).sum();
            assert_eq!(mass, n_examples as u64, "row {r}");
        }
    });
}

#[test]
fn prop_classifier_delta_wire_roundtrip_any_config() {
    // Task-tagged v3 frames round-trip for any geometry/width, and a
    // replica fed only the decoded delta reproduces the live classifier.
    cases(40, 122, |rng, case| {
        let widths = [CounterWidth::U8, CounterWidth::U16, CounterWidth::U32];
        let d = gen_dim(rng, 1, 8);
        // The Hadamard family needs p <= next_pow2(d + 2); clamping keeps
        // this sweep valid under STORM_TEST_HASH_FAMILY=hadamard.
        let max_p = (d + 2).next_power_of_two() as u32;
        let cfg = StormConfig {
            rows: 1 + (case % 20),
            power: (1 + (case % 5) as u32).min(max_p),
            saturating: true,
            counter_width: widths[case % widths.len()],
            task: Task::Classification,
            hash_family: test_hash_family(),
        };
        let seed = case as u64 ^ 0xC1FD;
        let mut sk = StormClassifierSketch::new(cfg, d, seed);
        let head = (rng.next_u64() % 20) as usize;
        for i in 0..head {
            let x = gen_ball_point(rng, d, 0.9);
            sk.insert_labelled(&x, if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        let snap = sk.snapshot();
        let mut replica = StormClassifierSketch::new(cfg, d, seed);
        replica.merge_from(&sk);
        let tail = (rng.next_u64() % 30) as usize;
        for i in 0..tail {
            let x = gen_ball_point(rng, d, 0.9);
            sk.insert_labelled(&x, if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        let delta = sk.delta_since(&snap, rng.next_u64() % 1000);
        assert_eq!(delta.count, tail as u64);
        assert_eq!(delta.cfg.task, Task::Classification);
        let back = decode_delta(&encode_delta(&delta)).unwrap();
        assert_eq!(back, delta);
        replica.apply_delta(&back);
        assert_eq!(replica.grid().counts_u32(), sk.grid().counts_u32());
        assert_eq!(replica.count(), sk.count());
    });
}

#[test]
fn prop_structured_family_delta_wire_roundtrip() {
    // Structured-family deltas ship as v3 frames carrying the family (and
    // the sparse family's density per-mille) on the wire. For any
    // geometry, width and density: round-trip is exact, the decoded
    // config names the family, and a replica fed only the decoded delta
    // reproduces the live structured sketch bit-for-bit.
    cases(30, 123, |rng, case| {
        let family = if case % 2 == 0 {
            HashFamily::Sparse { density_permille: 1 + (case as u16 % 1000) }
        } else {
            HashFamily::Hadamard
        };
        let dim = gen_dim(rng, 1, 10);
        // Hadamard selects p distinct coordinates of the padded
        // transform: p <= next_pow2(dim + 2).
        let max_p = (dim + 2).next_power_of_two() as u32;
        let cfg = StormConfig {
            rows: 1 + (case % 16),
            power: (1 + (case % 6) as u32).min(max_p),
            saturating: true,
            counter_width: test_counter_width(),
            hash_family: family,
            ..Default::default()
        };
        let seed = case as u64 ^ 0xFA417;
        let mut sk = StormSketch::new(cfg, dim, seed);
        let head = (rng.next_u64() % 20) as usize;
        for _ in 0..head {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let snap = sk.snapshot();
        let mut replica = StormSketch::new(cfg, dim, seed);
        replica.merge_from(&sk);
        let tail = (rng.next_u64() % 30) as usize;
        for _ in 0..tail {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let delta = sk.delta_since(&snap, case as u64);
        let bytes = encode_delta(&delta);
        assert_eq!(
            u16::from_le_bytes(bytes[4..6].try_into().unwrap()),
            3,
            "structured families always ship v3 ({family})"
        );
        let back = decode_delta(&bytes).unwrap();
        assert_eq!(back, delta, "{family}");
        assert_eq!(back.cfg.hash_family, family);
        replica.apply_delta(&back);
        assert_eq!(replica.grid().counts_u32(), sk.grid().counts_u32(), "{family}");
        assert_eq!(replica.count(), sk.count());
    });
}

#[test]
fn prop_hash_family_is_a_merge_barrier_on_the_wire() {
    // Deltas from every pair of DISTINCT families decode as
    // merge-incompatible — the family tag survives the wire and gates
    // apply_delta (the panic itself is unit-tested in sketch::delta).
    cases(20, 124, |rng, case| {
        let families = [
            HashFamily::Dense,
            HashFamily::Sparse { density_permille: 1 + (case as u16 % 1000) },
            HashFamily::Hadamard,
        ];
        let dim = gen_dim(rng, 1, 8);
        let max_p = (dim + 2).next_power_of_two() as u32;
        let base = StormConfig {
            rows: 1 + (case % 10),
            power: (1 + (case % 4) as u32).min(max_p),
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let seed = case as u64;
        let mut decoded = Vec::new();
        for &family in &families {
            let cfg = StormConfig { hash_family: family, ..base };
            let mut sk = StormSketch::new(cfg, dim, seed);
            let snap = sk.snapshot();
            for _ in 0..(1 + rng.next_u64() % 10) {
                sk.insert(&gen_ball_point(rng, dim, 0.9));
            }
            decoded.push(decode_delta(&encode_delta(&sk.delta_since(&snap, 1))).unwrap());
        }
        for (i, a) in decoded.iter().enumerate() {
            for (j, b) in decoded.iter().enumerate() {
                assert_eq!(
                    a.cfg.merge_compatible(&b.cfg),
                    i == j,
                    "families {} vs {}",
                    a.cfg.hash_family,
                    b.cfg.hash_family
                );
            }
        }
    });
}

#[test]
fn prop_privacy_off_frames_carry_no_bit_and_huge_epsilon_noise_is_zero() {
    // Privacy satellite, part 1: with privacy off, every wire frame is
    // the exact pre-privacy encoding — no privacy bit, bit-identical
    // re-encode (the exact bytes are pinned by the golden fixtures in
    // sketch::serialize and the Python wire mirror) — at every counter
    // width, hash family and task the CI matrix sweeps. With privacy on,
    // the frame upgrades to v3 with the bit set; at huge epsilon the
    // two-sided geometric mechanism degenerates to exactly zero noise;
    // and noise is a pure function of its seed, so the same release
    // always ships the same bytes (the retransmit no-double-spend
    // foundation).
    use storm::sketch::privacy::noise_delta;
    let task = test_task();
    cases(40, 125, |rng, case| {
        let d = gen_dim(rng, 1, 8);
        let max_p = (d + 2).next_power_of_two() as u32;
        let cfg = StormConfig {
            rows: 1 + (case % 12),
            power: (1 + (case % 5) as u32).min(max_p),
            saturating: true,
            counter_width: test_counter_width(),
            task,
            hash_family: test_hash_family(),
        };
        let seed = case as u64 ^ 0xB0FF;
        let delta = match task {
            Task::Regression => {
                let mut sk = StormSketch::new(cfg, d, seed);
                let snap = sk.snapshot();
                for _ in 0..(1 + rng.next_u64() % 20) {
                    sk.insert(&gen_ball_point(rng, d, 0.9));
                }
                sk.delta_since(&snap, case as u64)
            }
            Task::Classification => {
                let mut sk = StormClassifierSketch::new(cfg, d, seed);
                let snap = sk.snapshot();
                for i in 0..(1 + rng.next_u64() % 20) {
                    let x = gen_ball_point(rng, d, 0.9);
                    sk.insert_labelled(&x, if i % 2 == 0 { 1.0 } else { -1.0 });
                }
                sk.delta_since(&snap, case as u64)
            }
        };
        let bytes = encode_delta(&delta);
        let back = decode_delta(&bytes).unwrap();
        assert!(!back.private, "privacy off must never set the bit");
        assert_eq!(back, delta);
        assert_eq!(encode_delta(&back), bytes, "re-encode is byte-identical");
        // Huge epsilon: alpha underflows to 0 => zero noise, exactly.
        let mut huge = delta.clone();
        noise_delta(&mut huge, 1e9, seed ^ 0x17);
        assert!(huge.private);
        assert_eq!(huge.counts, delta.counts);
        assert_eq!(huge.count, delta.count);
        let pbytes = encode_delta(&huge);
        assert_eq!(
            u16::from_le_bytes(pbytes[4..6].try_into().unwrap()),
            3,
            "private frames always ship v3"
        );
        assert_eq!(decode_delta(&pbytes).unwrap(), huge);
        // Deterministic noise: same (epsilon, seed) => same bytes. The
        // CI privacy leg overrides the epsilon via STORM_TEST_PRIVACY.
        let knob = test_privacy_epsilon();
        let eps = if knob > 0.0 { knob } else { 0.7 };
        let mut a = delta.clone();
        let mut b = delta.clone();
        noise_delta(&mut a, eps, seed ^ 0x99);
        noise_delta(&mut b, eps, seed ^ 0x99);
        assert_eq!(a, b);
        assert_eq!(encode_delta(&a), encode_delta(&b));
    });
}

#[test]
fn prop_private_chaotic_fleet_is_deterministic_with_exact_accounting() {
    // Privacy satellite, part 2: under ANY seeded fault schedule a
    // private fleet still closes every round — so the driver's epsilon
    // ledger composes to exactly rounds x epsilon_per_round — keeps
    // example accounting exact (only counter cells are noised), and is
    // bit-for-bit reproducible: retransmitted frames re-ship the SAME
    // noised bytes (noise is a pure function of (family_seed, device,
    // epoch)), so catch-up traffic never draws fresh noise and never
    // double-spends the budget.
    let task = test_task();
    let knob = test_privacy_epsilon();
    let eps = if knob > 0.0 { knob } else { 0.4 };
    cases(6, 126, |rng, case| {
        let n_examples = 60 + (rng.next_u64() % 100) as usize;
        let devices = 2 + (case % 3);
        let rounds = 2 + (case % 3);
        let storm = StormConfig {
            rows: 6 + (case % 6),
            power: 3,
            saturating: true,
            counter_width: test_counter_width(),
            task,
            hash_family: test_hash_family(),
        };
        let ds = task_ds(n_examples, case as u64 ^ 0xD9, task);
        let family_seed = 0xD1CE ^ case as u64;
        let plan = FaultPlan::from_seed(rng.next_u64());
        let run = |eps: f64, plan: Option<FaultPlan>| {
            let fleet = FleetConfig {
                devices,
                batch: 16,
                channel_capacity: 2,
                link_latency_us: 0,
                link_bandwidth_bps: 0,
                sync_rounds: rounds,
                min_quorum: 0,
                faults_seed: None,
                device_counter_width: None,
                workers: 1 + case % 3,
                fan_in: 2,
                epsilon_per_round: eps,
                decay_keep_permille: 1000,
                seed: 0,
            };
            let streams = partition_streams(&ds, devices, None);
            run_fleet_model_chaos::<StormModel, _>(
                fleet,
                storm,
                Topology::Star,
                ds.dim() + 1,
                family_seed,
                streams,
                plan,
                |_, _| {},
            )
        };
        let a = run(eps, Some(plan));
        let b = run(eps, Some(plan));
        let ctx = format!("devices={devices} rounds={rounds} task={task}");
        assert_eq!(a.sketch.grid().counts_u32(), b.sketch.grid().counts_u32(), "{ctx}");
        assert_eq!(a.sketch.count(), b.sketch.count(), "{ctx}");
        assert_eq!(a.examples, n_examples as u64, "exact example accounting under DP: {ctx}");
        assert_eq!(a.rounds.len(), rounds, "every round closes => ledger = rounds x eps: {ctx}");
        // The noise actually moved the counters vs the exact run.
        let exact = run(0.0, Some(plan));
        assert_eq!(exact.examples, a.examples, "{ctx}");
        assert_ne!(
            a.sketch.grid().counts_u32(),
            exact.sketch.grid().counts_u32(),
            "noise was vacuous: {ctx}"
        );
    });
}

#[test]
fn prop_query_estimate_bounded() {
    // 0 <= raw query estimate <= 2 (both PRP arms can collide).
    cases(60, 106, |rng, case| {
        let dim = gen_dim(rng, 1, 10);
        let cfg = StormConfig {
            rows: 20,
            power: 4,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        for _ in 0..30 {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let q = gen_ball_point(rng, dim, 0.9);
        let v = sk.query(&q);
        assert!((0.0..=2.0 + 1e-12).contains(&v), "estimate {v} out of range");
    });
}

#[test]
fn prop_prp_insert_buckets_antipodal_structure() {
    // The two insert buckets correspond to z and -z under the same hash;
    // expected_count is symmetric g(t) = g(-t).
    cases(100, 107, |rng, case| {
        let dim = gen_dim(rng, 1, 10);
        let h = PairedRandomProjection::new(dim, 4, case as u64);
        let z = gen_ball_point(rng, dim, 0.9);
        let (b1, b2) = h.insert_buckets(&z);
        assert!(b1 < h.range() && b2 < h.range());
        let q = gen_ball_point(rng, dim, 0.9);
        let neg_q: Vec<f64> = q.iter().map(|v| -v).collect();
        assert_close(h.expected_count(&q, &z), h.expected_count(&neg_q, &z), 1e-12);
    });
}

#[test]
fn prop_insert_batch_bit_identical_to_scalar_inserts() {
    // The fused hash-bank batch path must reproduce the seed scalar
    // path's counter grid EXACTLY (same seed => same buckets => same
    // counts), across dims, row counts crossing the tile boundary, and
    // powers.
    cases(50, 109, |rng, case| {
        let dim = gen_dim(rng, 1, 14);
        let rows = 1 + (case % 41); // crosses the 16-row insert tile
        let p = 1 + (case % 8) as u32;
        let cfg = StormConfig {
            rows,
            power: p,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let n = 1 + (rng.next_u64() % 50) as usize;
        let data: Vec<Vec<f64>> = (0..n).map(|_| gen_ball_point(rng, dim, 0.95)).collect();
        let mut scalar = StormSketch::new(cfg, dim, case as u64);
        for z in &data {
            scalar.insert(z);
        }
        let mut fused = StormSketch::new(cfg, dim, case as u64);
        fused.insert_batch(&data);
        assert_eq!(
            scalar.grid().counts_u32(),
            fused.grid().counts_u32(),
            "dim={dim} rows={rows} p={p}"
        );
        assert_eq!(scalar.count(), fused.count());
    });
}

#[test]
fn prop_insert_batch_split_and_thread_invariant() {
    // Splitting a stream into arbitrary batches, and spreading rows over
    // scoped threads, must not change the grid.
    cases(30, 110, |rng, case| {
        let dim = gen_dim(rng, 1, 8);
        let cfg = StormConfig {
            rows: 24,
            power: 4,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let n = 20 + (rng.next_u64() % 40) as usize;
        let data: Vec<Vec<f64>> = (0..n).map(|_| gen_ball_point(rng, dim, 0.9)).collect();
        let seed = case as u64 ^ 0x5EED;
        let mut whole = StormSketch::new(cfg, dim, seed);
        whole.insert_batch(&data);
        let mut split = StormSketch::new(cfg, dim, seed);
        let mut rest: &[Vec<f64>] = &data;
        while !rest.is_empty() {
            let take = (1 + (rng.next_u64() as usize % 9)).min(rest.len());
            split.insert_batch(&rest[..take]);
            rest = &rest[take..];
        }
        let mut threaded = StormSketch::new(cfg, dim, seed);
        threaded.insert_batch_with_threads(&data, 1 + (case % 5));
        assert_eq!(whole.grid().counts_u32(), split.grid().counts_u32());
        assert_eq!(whole.grid().counts_u32(), threaded.grid().counts_u32());
        assert_eq!(whole.count(), split.count());
        assert_eq!(whole.count(), threaded.count());
    });
}

#[test]
fn prop_estimate_risk_batch_bit_identical_to_scalar() {
    // The fused batch query path must match scalar estimate_risk_scaled
    // exactly, for candidates inside the ball and far outside (rescale
    // path).
    cases(40, 111, |rng, case| {
        let dim = gen_dim(rng, 1, 10);
        let cfg = StormConfig {
            rows: 25,
            power: 4,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        let n = (rng.next_u64() % 60) as usize; // sometimes empty
        for _ in 0..n {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let mut cands: Vec<Vec<f64>> = Vec::new();
        for i in 0..12 {
            let mut q = gen_ball_point(rng, dim, 0.9);
            if i % 3 == 0 {
                for v in &mut q {
                    *v *= 8.0; // force the unit-ball rescale branch
                }
            }
            cands.push(q);
        }
        let mut out = Vec::new();
        sk.estimate_risk_batch(&cands, &mut out);
        assert_eq!(out.len(), cands.len());
        for (q, got) in cands.iter().zip(&out) {
            let want = sk.estimate_risk_scaled(q);
            assert!(
                got.to_bits() == want.to_bits(),
                "fused {got} != scalar {want} (dim={dim} n={n})"
            );
        }
    });
}

#[test]
fn prop_bank_pairs_match_per_row_hashes() {
    // The bank's fused shared-projection hashing agrees bucket-for-bucket
    // with the per-row PRP objects it was built from.
    cases(60, 112, |rng, case| {
        let dim = gen_dim(rng, 1, 12);
        let p = 1 + (case % 8) as u32;
        let cfg = StormConfig {
            rows: 9,
            power: p,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let sk = StormSketch::new(cfg, dim, case as u64);
        let bank = sk.bank();
        let z = gen_ball_point(rng, dim, 0.95);
        let tail = storm::lsh::bank::HashBank::mips_tail(&z);
        for (r, h) in sk.hashes().iter().enumerate() {
            assert_eq!(bank.data_pair(r, &z, tail), h.insert_buckets(&z));
        }
    });
}

#[test]
fn prop_scaled_estimates_invariant_to_theta_magnitude_beyond_ball() {
    // estimate_risk_scaled(c * theta~) is constant for c past the ball
    // radius (pure direction dependence) — the optimizer relies on this.
    cases(40, 108, |rng, case| {
        let dim = gen_dim(rng, 2, 8);
        let cfg = StormConfig {
            rows: 30,
            power: 4,
            saturating: true,
            counter_width: test_counter_width(),
            ..Default::default()
        };
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        for _ in 0..50 {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let mut q = gen_ball_point(rng, dim, 1.0);
        // Push far outside the ball.
        for v in &mut q {
            *v *= 5.0;
        }
        let r1 = sk.estimate_risk_scaled(&q);
        let q2: Vec<f64> = q.iter().map(|v| v * 3.0).collect();
        let r2 = sk.estimate_risk_scaled(&q2);
        assert_close(r1, r2, 1e-12);
    });
}
