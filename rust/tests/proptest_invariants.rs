//! Property-based invariant tests over randomized configurations (the
//! crate's seeded case-sweep framework stands in for proptest, which is
//! not in the offline vendor set).

use storm::config::StormConfig;
use storm::lsh::asym::{augment, Side};
use storm::lsh::prp::PairedRandomProjection;
use storm::lsh::srp::SignedRandomProjection;
use storm::lsh::LshFunction;
use storm::sketch::serialize::{decode, encode};
use storm::sketch::storm::StormSketch;
use storm::sketch::Sketch;
use storm::testing::{assert_close, cases, gen_ball_point, gen_dim};
use storm::util::mathx::{dot, norm2};
use storm::util::rng::Rng;

#[test]
fn prop_srp_hash_in_range_any_dim_and_power() {
    cases(200, 101, |rng, case| {
        let dim = gen_dim(rng, 1, 40);
        let p = 1 + (case % 12) as u32;
        let h = SignedRandomProjection::new(dim, p, case as u64);
        let x: Vec<f64> = (0..dim).map(|_| rng.uniform_range(-5.0, 5.0)).collect();
        assert!(h.hash(&x) < h.range());
    });
}

#[test]
fn prop_augmentation_unit_norm_and_ip_preserving() {
    cases(200, 102, |rng, _| {
        let dim = gen_dim(rng, 1, 30);
        let a = gen_ball_point(rng, dim, 0.999);
        let b = gen_ball_point(rng, dim, 0.999);
        let aq = augment(&a, Side::Query);
        let ab = augment(&b, Side::Data);
        assert_close(norm2(&aq), 1.0, 1e-9);
        assert_close(norm2(&ab), 1.0, 1e-9);
        assert_close(dot(&aq, &ab), dot(&a, &b), 1e-9);
    });
}

#[test]
fn prop_sketch_row_mass_is_2n() {
    // Invariant: every row's counters sum to exactly 2 * inserts.
    cases(60, 103, |rng, case| {
        let dim = gen_dim(rng, 1, 12);
        let rows = 1 + (case % 20);
        let p = 1 + (case % 6) as u32;
        let cfg = StormConfig { rows, power: p, saturating: true };
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        let n = 1 + (rng.next_u64() % 60) as usize;
        for _ in 0..n {
            sk.insert(&gen_ball_point(rng, dim, 0.95));
        }
        for r in 0..rows {
            let mass: u64 = sk.grid().row(r).iter().map(|&c| c as u64).sum();
            assert_eq!(mass, 2 * n as u64);
        }
        assert_eq!(sk.count(), n as u64);
    });
}

#[test]
fn prop_merge_commutative_and_associative() {
    cases(40, 104, |rng, case| {
        let cfg = StormConfig { rows: 8, power: 3, saturating: true };
        let dim = gen_dim(rng, 1, 8);
        let seed = case as u64;
        let mut mk = |rng: &mut storm::util::rng::Xoshiro256, n: usize| {
            let mut s = StormSketch::new(cfg, dim, seed);
            for _ in 0..n {
                s.insert(&gen_ball_point(rng, dim, 0.9));
            }
            s
        };
        let a = mk(rng, 10);
        let b = mk(rng, 15);
        let c = mk(rng, 7);
        // (a + b) + c == a + (b + c), and a + b == b + a.
        let mut ab = StormSketch::new(cfg, dim, seed);
        ab.merge_from(&a);
        ab.merge_from(&b);
        let mut ba = StormSketch::new(cfg, dim, seed);
        ba.merge_from(&b);
        ba.merge_from(&a);
        assert_eq!(ab.grid().data(), ba.grid().data());
        let mut abc1 = ab;
        abc1.merge_from(&c);
        let mut bc = StormSketch::new(cfg, dim, seed);
        bc.merge_from(&b);
        bc.merge_from(&c);
        let mut abc2 = StormSketch::new(cfg, dim, seed);
        abc2.merge_from(&a);
        abc2.merge_from(&bc);
        assert_eq!(abc1.grid().data(), abc2.grid().data());
        assert_eq!(abc1.count(), 32);
    });
}

#[test]
fn prop_wire_roundtrip_any_config() {
    cases(60, 105, |rng, case| {
        let rows = 1 + (case % 30);
        let p = 1 + (case % 8) as u32;
        let dim = gen_dim(rng, 1, 16);
        let cfg = StormConfig { rows, power: p, saturating: true };
        let mut sk = StormSketch::new(cfg, dim, case as u64 ^ 0xABCD);
        let n = (rng.next_u64() % 40) as usize;
        for _ in 0..n {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let back = decode(&encode(&sk)).unwrap();
        assert_eq!(back.grid().data(), sk.grid().data());
        assert_eq!(back.count(), sk.count());
        assert_eq!(back.dim(), sk.dim());
    });
}

#[test]
fn prop_query_estimate_bounded() {
    // 0 <= raw query estimate <= 2 (both PRP arms can collide).
    cases(60, 106, |rng, case| {
        let dim = gen_dim(rng, 1, 10);
        let cfg = StormConfig { rows: 20, power: 4, saturating: true };
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        for _ in 0..30 {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let q = gen_ball_point(rng, dim, 0.9);
        let v = sk.query(&q);
        assert!((0.0..=2.0 + 1e-12).contains(&v), "estimate {v} out of range");
    });
}

#[test]
fn prop_prp_insert_buckets_antipodal_structure() {
    // The two insert buckets correspond to z and -z under the same hash;
    // expected_count is symmetric g(t) = g(-t).
    cases(100, 107, |rng, case| {
        let dim = gen_dim(rng, 1, 10);
        let h = PairedRandomProjection::new(dim, 4, case as u64);
        let z = gen_ball_point(rng, dim, 0.9);
        let (b1, b2) = h.insert_buckets(&z);
        assert!(b1 < h.range() && b2 < h.range());
        let q = gen_ball_point(rng, dim, 0.9);
        let neg_q: Vec<f64> = q.iter().map(|v| -v).collect();
        assert_close(h.expected_count(&q, &z), h.expected_count(&neg_q, &z), 1e-12);
    });
}

#[test]
fn prop_insert_batch_bit_identical_to_scalar_inserts() {
    // The fused hash-bank batch path must reproduce the seed scalar
    // path's counter grid EXACTLY (same seed => same buckets => same
    // counts), across dims, row counts crossing the tile boundary, and
    // powers.
    cases(50, 109, |rng, case| {
        let dim = gen_dim(rng, 1, 14);
        let rows = 1 + (case % 41); // crosses the 16-row insert tile
        let p = 1 + (case % 8) as u32;
        let cfg = StormConfig { rows, power: p, saturating: true };
        let n = 1 + (rng.next_u64() % 50) as usize;
        let data: Vec<Vec<f64>> = (0..n).map(|_| gen_ball_point(rng, dim, 0.95)).collect();
        let mut scalar = StormSketch::new(cfg, dim, case as u64);
        for z in &data {
            scalar.insert(z);
        }
        let mut fused = StormSketch::new(cfg, dim, case as u64);
        fused.insert_batch(&data);
        assert_eq!(scalar.grid().data(), fused.grid().data(), "dim={dim} rows={rows} p={p}");
        assert_eq!(scalar.count(), fused.count());
    });
}

#[test]
fn prop_insert_batch_split_and_thread_invariant() {
    // Splitting a stream into arbitrary batches, and spreading rows over
    // scoped threads, must not change the grid.
    cases(30, 110, |rng, case| {
        let dim = gen_dim(rng, 1, 8);
        let cfg = StormConfig { rows: 24, power: 4, saturating: true };
        let n = 20 + (rng.next_u64() % 40) as usize;
        let data: Vec<Vec<f64>> = (0..n).map(|_| gen_ball_point(rng, dim, 0.9)).collect();
        let seed = case as u64 ^ 0x5EED;
        let mut whole = StormSketch::new(cfg, dim, seed);
        whole.insert_batch(&data);
        let mut split = StormSketch::new(cfg, dim, seed);
        let mut rest: &[Vec<f64>] = &data;
        while !rest.is_empty() {
            let take = (1 + (rng.next_u64() as usize % 9)).min(rest.len());
            split.insert_batch(&rest[..take]);
            rest = &rest[take..];
        }
        let mut threaded = StormSketch::new(cfg, dim, seed);
        threaded.insert_batch_with_threads(&data, 1 + (case % 5));
        assert_eq!(whole.grid().data(), split.grid().data());
        assert_eq!(whole.grid().data(), threaded.grid().data());
        assert_eq!(whole.count(), split.count());
        assert_eq!(whole.count(), threaded.count());
    });
}

#[test]
fn prop_estimate_risk_batch_bit_identical_to_scalar() {
    // The fused batch query path must match scalar estimate_risk_scaled
    // exactly, for candidates inside the ball and far outside (rescale
    // path).
    cases(40, 111, |rng, case| {
        let dim = gen_dim(rng, 1, 10);
        let cfg = StormConfig { rows: 25, power: 4, saturating: true };
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        let n = (rng.next_u64() % 60) as usize; // sometimes empty
        for _ in 0..n {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let mut cands: Vec<Vec<f64>> = Vec::new();
        for i in 0..12 {
            let mut q = gen_ball_point(rng, dim, 0.9);
            if i % 3 == 0 {
                for v in &mut q {
                    *v *= 8.0; // force the unit-ball rescale branch
                }
            }
            cands.push(q);
        }
        let mut out = Vec::new();
        sk.estimate_risk_batch(&cands, &mut out);
        assert_eq!(out.len(), cands.len());
        for (q, got) in cands.iter().zip(&out) {
            let want = sk.estimate_risk_scaled(q);
            assert!(
                got.to_bits() == want.to_bits(),
                "fused {got} != scalar {want} (dim={dim} n={n})"
            );
        }
    });
}

#[test]
fn prop_bank_pairs_match_per_row_hashes() {
    // The bank's fused shared-projection hashing agrees bucket-for-bucket
    // with the per-row PRP objects it was built from.
    cases(60, 112, |rng, case| {
        let dim = gen_dim(rng, 1, 12);
        let p = 1 + (case % 8) as u32;
        let cfg = StormConfig { rows: 9, power: p, saturating: true };
        let sk = StormSketch::new(cfg, dim, case as u64);
        let bank = sk.bank();
        let z = gen_ball_point(rng, dim, 0.95);
        let tail = storm::lsh::bank::HashBank::mips_tail(&z);
        for (r, h) in sk.hashes().iter().enumerate() {
            assert_eq!(bank.data_pair(r, &z, tail), h.insert_buckets(&z));
        }
    });
}

#[test]
fn prop_scaled_estimates_invariant_to_theta_magnitude_beyond_ball() {
    // estimate_risk_scaled(c * theta~) is constant for c past the ball
    // radius (pure direction dependence) — the optimizer relies on this.
    cases(40, 108, |rng, case| {
        let dim = gen_dim(rng, 2, 8);
        let cfg = StormConfig { rows: 30, power: 4, saturating: true };
        let mut sk = StormSketch::new(cfg, dim, case as u64);
        for _ in 0..50 {
            sk.insert(&gen_ball_point(rng, dim, 0.9));
        }
        let mut q = gen_ball_point(rng, dim, 1.0);
        // Push far outside the ball.
        for v in &mut q {
            *v *= 5.0;
        }
        let r1 = sk.estimate_risk_scaled(&q);
        let q2: Vec<f64> = q.iter().map(|v| v * 3.0).collect();
        let r2 = sk.estimate_risk_scaled(&q2);
        assert_close(r1, r2, 1e-12);
    });
}
