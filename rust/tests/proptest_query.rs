//! Property tests for the rank-1 incremental query engine
//! (`storm::lsh::query`): candidate-set risks must reproduce the
//! dense-materialized oracle across every hash family, counter width,
//! and task, and whole optimizer trajectories driven through
//! [`IncrementalOracle`] must match the dense path.
//!
//! Sweeps honour the framework knobs (`storm::testing`):
//! `STORM_TEST_CASES=<m>` multiplies case budgets,
//! `STORM_TEST_REPLAY=<seed>:<case>` replays one case, and
//! `STORM_TEST_WIDTH=u8|u16|u32` picks the counter width. The CI
//! `query-dense` leg re-runs this whole file with
//! `STORM_QUERY_INCREMENTAL=off`, which flips [`IncrementalOracle`] to
//! the dense-materialize fallback — the trajectory properties then pin
//! the fallback's bit-identity to the bare model oracle, while the
//! direct engine properties keep exercising the rank-1 kernels
//! themselves.

use storm::config::{HashFamily, StormConfig, Task};
use storm::lsh::query::{CandidateSet, Probe, QueryEngine};
use storm::optim::coord::{coordinate_descent, CoordConfig};
use storm::optim::dfo::{DfoConfig, DfoOptimizer};
use storm::optim::spsa::{spsa, SpsaConfig};
use storm::optim::{IncrementalOracle, RiskOracle};
use storm::sketch::model::StormModel;
use storm::sketch::RiskSketch;
use storm::testing::{assert_allclose, cases, gen_ball_point, gen_dim, test_counter_width};
use storm::util::rng::Xoshiro256;

const FAMILIES: [HashFamily; 3] = [
    HashFamily::Dense,
    HashFamily::Sparse { density_permille: 300 },
    HashFamily::Hadamard,
];

fn stream(rng: &mut Xoshiro256, task: Task, n: usize, d: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|i| match task {
            Task::Regression => gen_ball_point(rng, d + 1, 0.9),
            Task::Classification => {
                let mut z = gen_ball_point(rng, d, 0.9);
                z.push(if i % 2 == 0 { 1.0 } else { -1.0 });
                z
            }
        })
        .collect()
}

#[test]
fn prop_candidate_risks_match_dense_every_family_width_and_task() {
    // The engine's buckets are sign tests of the same real projections
    // the dense path computes, so on continuous random inputs (fp ties
    // are measure-zero) the estimates must agree bit for bit — in and
    // out of the unit ball, axis probes (including the label slot and a
    // value re-stating the base), and shared-direction antithetic pairs.
    cases(20, 301, |rng, case| {
        let width = test_counter_width();
        for &family in &FAMILIES {
            for task in [Task::Regression, Task::Classification] {
                let d = gen_dim(rng, 2, 10);
                let cfg = StormConfig {
                    rows: 10 + 10 * (case % 3),
                    power: 1 + (case % 5) as u32,
                    saturating: true,
                    counter_width: width,
                    hash_family: family,
                    task,
                    ..Default::default()
                };
                let mut model = StormModel::new(cfg, d + 1, case as u64 ^ 0x51EE);
                model.insert_batch(&stream(rng, task, 60, d));
                let mut base = gen_ball_point(rng, d, 0.7);
                if case % 4 == 0 {
                    // Far out of the ball: every probe rescales.
                    for v in &mut base {
                        *v *= 6.0;
                    }
                }
                base.push(-1.0);
                let mut dirs =
                    vec![gen_ball_point(rng, d + 1, 1.0), gen_ball_point(rng, d + 1, 1.0)];
                for u in &mut dirs {
                    u[d] = 0.0;
                }
                let probes = [
                    Probe::Base,
                    Probe::Axis { k: case % d, value: 0.4 },
                    Probe::Axis { k: (case + 1) % d, value: base[(case + 1) % d] },
                    Probe::Axis { k: d, value: -1.0 },
                    Probe::Dir { dir: 0, step: 0.15 },
                    Probe::Dir { dir: 0, step: -0.15 },
                    Probe::Dir { dir: 1, step: 1.1 },
                ];
                let set = CandidateSet { base: &base, dirs: &dirs, probes: &probes };
                let mut engine = QueryEngine::new(model.bank());
                let mut inc = Vec::new();
                model.estimate_risk_candidates(&mut engine, &set, &mut inc);
                let mut dense_cands = Vec::new();
                set.materialize(&mut dense_cands);
                let mut dense = Vec::new();
                model.estimate_risk_batch(&dense_cands, &mut dense);
                assert_eq!(inc.len(), dense.len());
                for (i, (a, b)) in inc.iter().zip(&dense).enumerate() {
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "{family} {task:?} d={d} probe {i}: incremental {a} != dense {b}"
                    );
                }
                // The second serve hits the cached base — still identical.
                let mut again = Vec::new();
                model.estimate_risk_candidates(&mut engine, &set, &mut again);
                assert_eq!(inc, again);
            }
        }
    });
}

#[test]
fn prop_empty_model_candidates_are_all_zero() {
    cases(8, 303, |rng, case| {
        let d = gen_dim(rng, 2, 6);
        let task = if case % 2 == 0 { Task::Regression } else { Task::Classification };
        let cfg = StormConfig { rows: 8, power: 3, saturating: true, task, ..Default::default() };
        let model = StormModel::new(cfg, d + 1, 3);
        let mut base = gen_ball_point(rng, d, 0.5);
        base.push(-1.0);
        let probes = [Probe::Base, Probe::Axis { k: 0, value: 0.1 }];
        let set = CandidateSet { base: &base, dirs: &[], probes: &probes };
        let mut engine = QueryEngine::new(model.bank());
        let mut out = vec![7.0; 5]; // stale scratch must be cleared
        model.estimate_risk_candidates(&mut engine, &set, &mut out);
        assert_eq!(out, vec![0.0, 0.0]);
    });
}

#[test]
fn prop_dfo_trajectory_matches_dense_path() {
    // End to end: the same optimizer seed driven through the
    // IncrementalOracle must land on the same model as the bare
    // dense-batch oracle. Estimates are bit-identical wherever no fp
    // bucket tie occurs (measure-zero on random data), so the
    // trajectories agree to fp-noise tolerance.
    cases(4, 304, |rng, case| {
        let d = 3 + case % 3;
        let task = if case % 2 == 0 { Task::Regression } else { Task::Classification };
        let cfg = StormConfig {
            rows: 40,
            power: 3,
            saturating: true,
            hash_family: FAMILIES[case % 3],
            task,
            ..Default::default()
        };
        let mut model = StormModel::new(cfg, d + 1, 11 + case as u64);
        model.insert_batch(&stream(rng, task, 150, d));
        let ocfg = DfoConfig { queries: 6, sigma: 0.15, step: 0.1, iters: 25, seed: 17 };
        let dense = DfoOptimizer::new(ocfg, d).run(&model, 25);
        let oracle = IncrementalOracle::new(&model);
        let inc = DfoOptimizer::new(ocfg, d).run(&oracle, 25);
        assert_allclose(&dense, &inc, 1e-12);
        assert_eq!(oracle.evals(), 25 * 6, "k queries per step, no baseline");
    });
}

#[test]
fn prop_coordinate_descent_trajectory_matches_dense_path() {
    cases(4, 305, |rng, case| {
        let d = 3 + case % 2;
        let task = if case % 2 == 0 { Task::Classification } else { Task::Regression };
        let cfg = StormConfig {
            rows: 50,
            power: 3,
            saturating: true,
            hash_family: FAMILIES[(case + 1) % 3],
            task,
            ..Default::default()
        };
        let mut model = StormModel::new(cfg, d + 1, 23 + case as u64);
        model.insert_batch(&stream(rng, task, 150, d));
        let ccfg = CoordConfig { sweeps: 3, radius: 0.5, shrink: 0.6, section_iters: 6 };
        let dense = coordinate_descent(&model, ccfg);
        let inc = coordinate_descent(&IncrementalOracle::new(&model), ccfg);
        assert_allclose(&dense.theta, &inc.theta, 1e-12);
        assert_allclose(&dense.trace, &inc.trace, 1e-12);
        assert_eq!(dense.evals, inc.evals);
    });
}

#[test]
fn prop_spsa_trajectory_matches_dense_path() {
    cases(4, 306, |rng, case| {
        let d = 2 + case % 3;
        let task = if case % 2 == 0 { Task::Regression } else { Task::Classification };
        let cfg = StormConfig {
            rows: 40,
            power: 3,
            saturating: true,
            hash_family: FAMILIES[(case + 2) % 3],
            task,
            ..Default::default()
        };
        let mut model = StormModel::new(cfg, d + 1, 31 + case as u64);
        model.insert_batch(&stream(rng, task, 120, d));
        let scfg = SpsaConfig { c: 0.2, a: 0.1, iters: 60, seed: 29 };
        let dense = spsa(&model, scfg);
        let inc = spsa(&IncrementalOracle::new(&model), scfg);
        assert_allclose(&dense, &inc, 1e-12);
    });
}

#[test]
fn coarse_step_candidates_are_bit_identical_to_dense() {
    // Exact-equality pin at coarse steps where fp ties are impossible:
    // dyadic-rational base/directions/values, ±1 sparse planes, and
    // in-ball candidates (the classifier head skips the augmented -1, so
    // s = 1 and no rescale rounding exists on either path). Every
    // intermediate product and sum is exactly representable, so the
    // incremental estimates equal the dense ones bit for bit — not just
    // tie-free-equal.
    let d = 8;
    let cfg = StormConfig {
        rows: 12,
        power: 5,
        saturating: true,
        hash_family: HashFamily::Sparse { density_permille: 400 },
        task: Task::Classification,
        ..Default::default()
    };
    let mut model = StormModel::new(cfg, d + 1, 0xC0A5);
    let mut rng = Xoshiro256::new(41);
    model.insert_batch(&stream(&mut rng, Task::Classification, 100, d));
    let mut base: Vec<f64> = (0..d).map(|i| (i as f64 - 4.0) / 16.0).collect();
    base.push(-1.0);
    let mut dir: Vec<f64> = (0..d).map(|i| if i % 2 == 0 { 0.25 } else { -0.125 }).collect();
    dir.push(0.0);
    let dirs = vec![dir];
    let probes = [
        Probe::Base,
        Probe::Axis { k: 2, value: 0.375 },
        Probe::Axis { k: 6, value: -0.5 },
        Probe::Dir { dir: 0, step: 0.25 },
        Probe::Dir { dir: 0, step: -0.25 },
        Probe::Axis { k: d, value: -1.0 },
    ];
    let set = CandidateSet { base: &base, dirs: &dirs, probes: &probes };
    let mut engine = QueryEngine::new(model.bank());
    let mut inc = Vec::new();
    model.estimate_risk_candidates(&mut engine, &set, &mut inc);
    let mut dense_cands = Vec::new();
    set.materialize(&mut dense_cands);
    let mut dense = Vec::new();
    model.estimate_risk_batch(&dense_cands, &mut dense);
    assert_eq!(inc.len(), dense.len());
    for (i, (a, b)) in inc.iter().zip(&dense).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "probe {i}: {a} vs {b}");
    }
}
