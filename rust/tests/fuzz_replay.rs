//! Stable-Rust replay of the checked-in fuzz seed corpus.
//!
//! The fuzz targets under `fuzz/fuzz_targets/` only build with cargo-fuzz
//! on nightly; this test re-runs the exact same invariants over every
//! seed in `fuzz/corpus/` on stable, so tier-1 CI catches a regression
//! on any input a past fuzzing run (or a hand-written malformed frame)
//! found interesting. Each replay also floors the corpus size — a seed
//! directory that silently shrinks fails loudly here.

use std::fs;
use std::path::{Path, PathBuf};

use storm::config::HashFamily;
use storm::sketch::serialize::{
    decode, decode_delta, encode, encode_delta_v3, fuzz_varint_stream, varint_to_bytes,
};

fn corpus_dir(target: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fuzz/corpus").join(target)
}

/// Every seed for `target`, sorted by file name for stable replay order.
fn seeds(target: &str, min: usize) -> Vec<(String, Vec<u8>)> {
    let dir = corpus_dir(target);
    let mut out: Vec<(String, Vec<u8>)> = fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("fuzz corpus dir {} missing: {e}", dir.display()))
        .map(|entry| {
            let entry = entry.expect("corpus dir entry");
            let name = entry.file_name().to_string_lossy().into_owned();
            let bytes = fs::read(entry.path()).expect("corpus seed readable");
            (name, bytes)
        })
        .collect();
    out.sort();
    assert!(out.len() >= min, "{target} corpus shrank: {} < {min} seeds", out.len());
    out
}

/// Mirror of `fuzz_targets/decode.rs`: no panic on any seed, and every
/// dense-family frame that decodes survives an encode/decode round trip.
#[test]
fn replay_decode_corpus() {
    let mut ok = 0usize;
    for (name, data) in seeds("decode", 20) {
        if let Ok(sketch) = decode(&data) {
            ok += 1;
            if sketch.config().hash_family == HashFamily::Dense {
                let bytes = encode(&sketch);
                let again = decode(&bytes)
                    .unwrap_or_else(|e| panic!("{name}: re-encoded frame failed: {e}"));
                assert_eq!(again.grid().counts_u32(), sketch.grid().counts_u32(), "{name}");
                assert_eq!(again.count(), sketch.count(), "{name}");
                assert_eq!(again.seed(), sketch.seed(), "{name}");
                assert_eq!(again.dim(), sketch.dim(), "{name}");
            }
        }
    }
    // The golden regression frames must keep decoding (classification
    // goldens are rejected by the full-sketch path by design).
    assert!(ok >= 10, "only {ok} decode seeds parsed — golden frames regressed");
}

/// Mirror of `fuzz_targets/decode_delta.rs`: no panic on any seed, and
/// every decodable frame is a fixed point of the v3 re-encode.
#[test]
fn replay_decode_delta_corpus() {
    let mut ok = 0usize;
    for (name, data) in seeds("decode_delta", 20) {
        if let Ok(delta) = decode_delta(&data) {
            ok += 1;
            let bytes = encode_delta_v3(&delta);
            let again = decode_delta(&bytes)
                .unwrap_or_else(|e| panic!("{name}: re-encoded delta failed: {e}"));
            assert_eq!(delta, again, "{name}: delta round-trip drifted");
        }
    }
    // All fifteen golden frames (v1/v2/v3, every width/family/task/privacy
    // combination) must keep decoding as deltas.
    assert!(ok >= 15, "only {ok} delta seeds parsed — golden frames regressed");
}

/// Mirror of `fuzz_targets/varint.rs`: no panic on any seed, and every
/// decoded value re-encodes canonically.
#[test]
fn replay_varint_corpus() {
    let mut ok = 0usize;
    for (name, data) in seeds("varint", 8) {
        if let Ok(values) = fuzz_varint_stream(&data) {
            ok += 1;
            for v in values {
                let bytes = varint_to_bytes(v);
                let back = fuzz_varint_stream(&bytes)
                    .unwrap_or_else(|e| panic!("{name}: canonical varint failed: {e}"));
                assert_eq!(back, vec![v], "{name}: varint round-trip drifted");
            }
        }
    }
    assert!(ok >= 6, "only {ok} varint seeds parsed — boundary seeds regressed");
}
