//! System-level integration: the whole pure-rust stack composed end to
//! end — datasets, scaling, fleet, sketch algebra, optimizers, baselines,
//! checkpointing — without the XLA runtime (that path is covered by
//! `integration_runtime.rs`).

use storm::baselines::CompressedRegression;
use storm::config::{FleetConfig, OptimizerConfig, RunConfig, StormConfig};
use storm::coordinator::driver::{train, QueryBackend};
use storm::coordinator::state::TrainingState;
use storm::data::registry;
use storm::data::scale::scale_to_unit_ball;
use storm::edge::topology::Topology;
use storm::linalg::solve::mse;
use storm::sketch::serialize::{decode, encode};
use storm::sketch::storm::StormSketch;

fn base_cfg(dataset: &str) -> RunConfig {
    RunConfig {
        dataset: dataset.to_string(),
        storm: StormConfig { rows: 200, power: 4, saturating: true, ..Default::default() },
        optimizer: OptimizerConfig { queries: 8, sigma: 0.3, step: 0.6, iters: 250, seed: 3 },
        fleet: FleetConfig {
            devices: 4,
            batch: 64,
            channel_capacity: 8,
            link_latency_us: 0,
            link_bandwidth_bps: 0,
            sync_rounds: 1,
            min_quorum: 0,
            faults_seed: None,
            device_counter_width: None,
            workers: 0,
            fan_in: 2,
            epsilon_per_round: 0.0,
            decay_keep_permille: 1000,
            seed: 2,
        },
        artifacts_dir: None,
    }
}

#[test]
fn full_pipeline_on_each_table1_dataset() {
    for name in registry::TABLE1_NAMES {
        let cfg = base_cfg(name);
        let ds = registry::load(name, 3).unwrap();
        let n = ds.len() as u64;
        let report = train(&cfg, ds, Topology::Star, QueryBackend::Rust).unwrap();
        assert_eq!(report.examples, n, "{name}");
        assert!(report.mse_storm.is_finite(), "{name}");
        assert!(report.mse_ls <= report.mse_storm + 1e-12, "{name}: LS must be the floor");
        assert!(report.network_bytes > 0, "{name}");
        assert_eq!(report.theta.len(), registry::info(name).unwrap().d);
    }
}

#[test]
fn training_is_deterministic_given_seeds() {
    let cfg = base_cfg("autos");
    let a = train(&cfg, registry::load("autos", 3).unwrap(), Topology::Star, QueryBackend::Rust)
        .unwrap();
    let b = train(&cfg, registry::load("autos", 3).unwrap(), Topology::Star, QueryBackend::Rust)
        .unwrap();
    assert_eq!(a.theta, b.theta);
    assert_eq!(a.mse_storm, b.mse_storm);
}

#[test]
fn sketches_travel_through_wire_format_between_fleet_stages() {
    // Simulate a device shipping to a foreign aggregator process: encode,
    // decode, merge, train — the decoded sketch must train identically.
    let mut ds = registry::load("airfoil", 5).unwrap();
    scale_to_unit_ball(&mut ds, 0.9);
    let cfg = StormConfig { rows: 150, power: 4, saturating: true, ..Default::default() };
    let mut local = StormSketch::new(cfg, ds.dim() + 1, 11);
    for i in 0..ds.len() {
        local.insert(&ds.augmented(i));
    }
    let remote = decode(&encode(&local)).unwrap();
    let ocfg = OptimizerConfig { queries: 8, sigma: 0.3, step: 0.6, iters: 150, seed: 1 };
    let t_local = storm::optim::dfo::DfoOptimizer::new(ocfg, ds.dim()).run(&local, 150);
    let t_remote = storm::optim::dfo::DfoOptimizer::new(ocfg, ds.dim()).run(&remote, 150);
    assert_eq!(t_local, t_remote);
}

#[test]
fn checkpoint_roundtrip_through_driver() {
    let cfg = base_cfg("autos");
    let report = train(&cfg, registry::load("autos", 3).unwrap(), Topology::Star, QueryBackend::Rust)
        .unwrap();
    let state = TrainingState {
        dataset: report.dataset.clone(),
        iter: cfg.optimizer.iters,
        theta: report.theta.clone(),
        trace: report.trace.clone(),
        rounds: report.rounds.iter().map(|r| (r.round, r.risk, r.bytes)).collect(),
    };
    let path = std::env::temp_dir().join("storm_integration_ckpt.txt");
    state.save(&path).unwrap();
    let back = TrainingState::load(&path).unwrap();
    assert_eq!(back.theta, report.theta);
    assert_eq!(back.trace.len(), report.trace.len());
}

#[test]
fn baselines_and_storm_share_memory_accounting() {
    // Figure-4 prerequisite: all methods quantize budgets consistently.
    let mut ds = registry::load("airfoil", 7).unwrap();
    scale_to_unit_ball(&mut ds, 0.9);
    let budget = storm::baselines::sample_bytes(64, ds.dim());
    for method in [
        &storm::baselines::random_sampling::RandomSampling as &dyn CompressedRegression,
        &storm::baselines::leverage::LeverageSampling,
        &storm::baselines::cw::ClarksonWoodruff,
    ] {
        let (theta, bytes) = method.fit(&ds, budget, 1);
        assert_eq!(theta.len(), ds.dim(), "{}", method.name());
        assert!(bytes <= budget, "{} used {bytes} > {budget}", method.name());
        assert!(mse(&ds.x, &ds.y, &theta).is_finite(), "{}", method.name());
    }
}

#[test]
fn chaotic_fleet_matches_ideal_fleet_counters_end_to_end() {
    // Real registry dataset, full fleet stack: an ideal network and a
    // seeded chaotic network (drops, duplicates, reordering, straggler
    // rounds, one crash/restart, partial quorum) must produce identical
    // leader counters — resilience costs bytes, never correctness.
    let mut ds = registry::load("autos", 9).unwrap();
    scale_to_unit_ball(&mut ds, 0.9);
    let storm = StormConfig { rows: 120, power: 4, saturating: true, ..Default::default() };
    let mk = |faults: Option<u64>, quorum: usize| {
        let mut fleet = base_cfg("autos").fleet;
        fleet.devices = 5;
        fleet.sync_rounds = 4;
        fleet.faults_seed = faults;
        fleet.min_quorum = quorum;
        let streams = storm::data::stream::partition_streams(&ds, 5, None);
        storm::edge::fleet::run_fleet(fleet, storm, Topology::Star, ds.dim() + 1, 31, streams)
    };
    let ideal = mk(None, 0);
    let chaotic = mk(Some(0xFEED), 2);
    assert_eq!(ideal.sketch.grid().counts_u32(), chaotic.sketch.grid().counts_u32());
    assert_eq!(ideal.sketch.count(), chaotic.sketch.count());
    assert_eq!(ideal.examples, chaotic.examples);
    assert_eq!(ideal.faults.total(), 0);
    assert!(chaotic.faults.total() > 0, "chaos was vacuous");
    assert_eq!(chaotic.rounds.len(), 4, "all rounds close under chaos");
}

/// Cheap procedural stream so the scale smoke costs bytes per device,
/// not a dataset shard per device.
struct SmokeStream {
    left: usize,
    state: u64,
}

impl storm::data::stream::StreamSource for SmokeStream {
    fn next_example(&mut self) -> Option<storm::data::stream::Example> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        self.state = self.state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let u = |shift: u32| ((self.state >> shift) & 0xFFFF) as f64 / 65536.0 - 0.5;
        Some(vec![u(3), u(19), u(35), u(51)])
    }

    fn remaining_hint(&self) -> Option<usize> {
        Some(self.left)
    }
}

/// CI `scale-smoke` leg: a 10k-device fleet through the worker-pool
/// executor — star and fan-in-capped deep tree — must finish a 2-round
/// sync in seconds, not minutes, and account every example exactly.
/// Ignored by default (it is a wall-clock assertion, not a logic test);
/// CI runs it with `cargo test -- --ignored scale_smoke`.
#[test]
#[ignore = "scale smoke: run explicitly (CI scale-smoke leg)"]
fn scale_smoke_10k_devices_two_rounds() {
    use storm::data::stream::StreamSource;
    let devices = 10_000usize;
    let per_device = 4usize;
    let storm = StormConfig { rows: 8, power: 3, saturating: true, ..Default::default() };
    for topo in [Topology::Star, Topology::Deep { max_fan_in: 16 }] {
        let mut fleet = base_cfg("autos").fleet;
        fleet.devices = devices;
        fleet.batch = 4;
        fleet.sync_rounds = 2;
        fleet.workers = 2;
        fleet.device_counter_width = Some(storm::config::CounterWidth::U8);
        let streams: Vec<Box<dyn StreamSource>> = (0..devices)
            .map(|d| {
                Box::new(SmokeStream { left: per_device, state: d as u64 + 1 })
                    as Box<dyn StreamSource>
            })
            .collect();
        let r = storm::edge::fleet::run_fleet(fleet, storm, topo, 4, 17, streams);
        assert_eq!(r.examples, (devices * per_device) as u64, "{topo:?}");
        assert_eq!(r.rounds.len(), 2, "{topo:?}");
        assert_eq!(r.sketch.count(), (devices * per_device) as u64, "{topo:?}");
        assert!(
            r.wall_secs < 60.0,
            "{topo:?}: 10k-device round took {:.1}s — executor scaling regressed",
            r.wall_secs
        );
    }
}

#[test]
fn fleet_with_slow_links_still_exact() {
    // Latency + tight channels stress the backpressure path — across
    // multiple sync rounds; per-round counters must remain exactly
    // mergeable so the trained models agree bit-for-bit.
    let mut cfg = base_cfg("autos");
    cfg.fleet.link_latency_us = 500;
    cfg.fleet.channel_capacity = 1;
    cfg.fleet.devices = 6;
    cfg.fleet.sync_rounds = 3;
    let a = train(&cfg, registry::load("autos", 3).unwrap(), Topology::Chain, QueryBackend::Rust)
        .unwrap();
    let mut fast = base_cfg("autos");
    fast.fleet.devices = 6;
    fast.fleet.sync_rounds = 3;
    let b = train(&fast, registry::load("autos", 3).unwrap(), Topology::Star, QueryBackend::Rust)
        .unwrap();
    // Identical per-round merged counters => identical training outcome.
    assert_eq!(a.theta, b.theta);
    assert_eq!(a.rounds.len(), 3);
}
