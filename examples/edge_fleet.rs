//! **End-to-end system driver** — the full three-layer stack on a real
//! (small) workload, proving all layers compose:
//!
//! 1. a parkinsons-scale stream (5.8k x 21) is partitioned over 8
//!    simulated edge devices;
//! 2. each device sketches its local stream one-pass and ships compact
//!    sketch deltas over star-topology links with bounded channels
//!    (backpressure) and a modelled radio link;
//! 3. the leader merges the deltas and trains a linear model by
//!    derivative-free optimization, with every risk query executed by the
//!    **AOT-compiled XLA artifact** (Pallas projection kernel + one-hot
//!    histogram, lowered at build time by `make artifacts`) through the
//!    PJRT runtime — python is not running anywhere in this binary;
//! 4. the run reports loss trace, traffic, energy and the comparison to
//!    exact least squares. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```text
//! make artifacts && cargo run --release --example edge_fleet
//! ```

use storm::config::{FleetConfig, OptimizerConfig, RunConfig, StormConfig};
use storm::coordinator::driver::{train, QueryBackend};
use storm::data::dataset::Dataset;
use storm::data::registry;
use storm::edge::energy::EnergyModel;
use storm::edge::topology::Topology;
use storm::util::rng::{Rng, Xoshiro256};

/// Draw `n` rows with replacement — a long-running stream from the same
/// sensor distribution.
fn resample(base: &Dataset, n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::new(seed);
    let idx: Vec<usize> = (0..n).map(|_| rng.below(base.len() as u64) as usize).collect();
    let mut ds = base.subset(&idx, "50k");
    ds.name = "airfoil-50k".to_string();
    ds
}

fn main() {
    storm::util::logging::init();
    let cfg = RunConfig {
        dataset: "airfoil-50k".to_string(),
        // R = 1000 (64 KB sketch): the surrogate landscape flattens with
        // dimension and the sketch-family bias scales as 1/sqrt(R), so a
        // generous row budget is what makes real-d training effective
        // (see EXPERIMENTS.md §SNR for the measured signal/bias numbers).
        storm: StormConfig { rows: 1000, power: 4, saturating: true, ..Default::default() },
        optimizer: OptimizerConfig { queries: 8, sigma: 0.3, step: 0.6, iters: 600, seed: 1 },
        fleet: FleetConfig {
            devices: 8,
            batch: 64,
            channel_capacity: 4,
            link_latency_us: 200,     // LTE-class RTT share
            link_bandwidth_bps: 1_000_000, // 1 MB/s uplink
            // Online mode: 8 delta sync rounds; DFO trains between rounds
            // against the leader's evolving sketch while devices stream.
            sync_rounds: 8,
            // Ideal network here; pass a faults seed (CLI --faults-seed)
            // to rehearse the same run under seeded chaos.
            min_quorum: 0,
            faults_seed: None,
            device_counter_width: None,
            // Worker-pool executor: 0 = one worker per hardware core.
            workers: 0,
            fan_in: 2,
            // Delta-level DP and leader decay both off: the seed pipeline.
            epsilon_per_round: 0.0,
            decay_keep_permille: 1000,
            seed: 17,
        },
        artifacts_dir: Some("artifacts".to_string()),
    };
    // A realistic edge workload: a long-running sensor stream. We draw
    // 50k examples from the airfoil distribution — the sketch absorbs all
    // of them at constant memory and constant network cost, which is the
    // regime the paper targets (the 1.4k-row base table alone is too
    // small for sketch shipping to amortize).
    let base = registry::load("airfoil", cfg.optimizer.seed).expect("dataset");
    let ds = resample(&base, 50_000, 77);
    let raw_bytes = ds.raw_bytes() as u64;
    let n = ds.len() as u64;

    let backend = if std::path::Path::new("artifacts/manifest.toml").exists() {
        QueryBackend::Xla
    } else {
        eprintln!("WARNING: artifacts/ missing — falling back to the pure-rust backend.");
        eprintln!("         Run `make artifacts` first for the full three-layer stack.");
        QueryBackend::Rust
    };

    let report = train(&cfg, ds, Topology::Star, backend).expect("training");

    println!("== edge_fleet end-to-end report ==");
    println!("backend          : {:?}", report.backend);
    println!("{}", report.summary());
    println!(
        "fleet            : {} devices (star), {} examples, {:.2}s wall",
        cfg.fleet.devices, report.examples, report.fleet_wall_secs
    );
    println!(
        "network          : {} bytes shipped (raw data would be {} bytes — {:.0}x reduction)",
        report.network_bytes,
        report.raw_bytes,
        report.raw_bytes as f64 / report.network_bytes.max(1) as f64
    );
    println!("training         : {:.2}s for {} DFO iters", report.train_wall_secs, cfg.optimizer.iters);
    // The anytime curve: risk/bytes per sync round — the model improved
    // while the fleet was still streaming.
    println!("sync rounds (examples seen, net bytes, est. risk):");
    for r in &report.rounds {
        println!(
            "  round {:>2}  examples {:>6}  bytes {:>8}  risk {:.5}",
            r.round, r.examples, r.bytes, r.risk
        );
    }
    // Loss curve (subsampled).
    println!("loss trace (estimated surrogate risk):");
    let stride = (report.trace.len() / 10).max(1);
    for (it, risk) in report.trace.iter().step_by(stride) {
        println!("  iter {it:>4}  risk {risk:.5}");
    }
    // Energy accounting.
    let model = EnergyModel::default();
    let ratio = model.savings_ratio(n, report.network_bytes, raw_bytes);
    println!(
        "energy           : sketch path {:.3} J vs raw upload {:.3} J  ({ratio:.1}x saving)",
        model.storm_energy(n, report.network_bytes).total(),
        model.raw_energy(raw_bytes).total(),
    );
    println!(
        "verdict          : storm/ls mse ratio {:.2}, param err {:.3}",
        report.mse_storm / report.mse_ls.max(1e-300),
        report.param_err
    );
}
