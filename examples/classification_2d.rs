//! Max-margin classification with STORM (Theorem 3) — end to end through
//! the task-generic pipeline: `task = classification` sends a labelled
//! 2-D stream through the edge fleet (devices sketch with the margin
//! hash, ship task-tagged deltas, the leader merges) and the driver
//! trains the separating hyperplane from the counters alone with the
//! same DFO loop regression uses.
//!
//! ```text
//! cargo run --release --example classification_2d
//! ```

use storm::config::{RunConfig, Task};
use storm::coordinator::driver::{train, QueryBackend};
use storm::data::registry;
use storm::edge::topology::Topology;

fn main() {
    let mut cfg = RunConfig {
        dataset: "synth2d-clf".to_string(),
        ..Default::default()
    };
    cfg.storm.task = Task::Classification;
    cfg.storm.rows = 600;
    cfg.storm.power = 2; // convex margin loss; p = 1 is the paper's fig-5 setting
    cfg.optimizer.iters = 400;
    cfg.optimizer.sigma = 0.3;
    cfg.optimizer.step = 0.6;
    cfg.optimizer.seed = 13;
    cfg.fleet.devices = 4;
    cfg.fleet.sync_rounds = 3;

    let ds = registry::load(&cfg.dataset, cfg.optimizer.seed).expect("registry dataset");
    let report = train(&cfg, ds, Topology::Star, QueryBackend::Rust).expect("train");

    println!("{}", report.summary());
    println!(
        "sketched {} labelled points into {} leader bytes over {} rounds",
        report.examples,
        report.sketch_bytes,
        report.rounds.len(),
    );
    println!(
        "hyperplane normal = ({:+.3}, {:+.3}); exact margin risk = {:.4}",
        report.theta[0], report.theta[1], report.mse_storm,
    );
    let acc = report.accuracy.expect("classification reports accuracy");
    println!("training accuracy      = {:.1}%", acc * 100.0);
    assert!(acc > 0.75, "separable blobs should classify well");
}
