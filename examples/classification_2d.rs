//! Max-margin classification with STORM (Theorem 3): sketch a labelled
//! 2-D stream with the asymmetric margin hash, then find the separating
//! hyperplane from the counters alone.
//!
//! ```text
//! cargo run --release --example classification_2d
//! ```

use storm::config::StormConfig;
use storm::data::synthetic;
use storm::loss::margin::accuracy;
use storm::sketch::storm::StormClassifierSketch;

fn main() {
    let mut ds = synthetic::synth2d_classification(1500, 0.8, 0.25, 13);
    // Scale features into the unit ball (labels fold into the hash sign).
    let max_norm = (0..ds.len())
        .map(|i| storm::util::mathx::norm2(ds.x.row(i)))
        .fold(0.0f64, f64::max);
    ds.x.scale(0.9 / max_norm);
    let xs: Vec<Vec<f64>> = (0..ds.len()).map(|i| ds.x.row(i).to_vec()).collect();

    // Paper setting for Figure 5: p = 1, R = 100.
    let cfg = StormConfig { rows: 100, power: 1, saturating: true, ..Default::default() };
    let mut sketch = StormClassifierSketch::new(cfg, 2, 29);
    for (x, y) in xs.iter().zip(&ds.y) {
        sketch.insert_labelled(x, *y);
    }
    println!(
        "sketched {} labelled points into {} bytes",
        sketch.count(),
        sketch.bytes()
    );

    // The classifier is a direction: sweep the angle, query the sketch.
    // (Derivative-free optimization over 1 angle parameter — the margin
    // loss estimate is the only training signal.)
    let mut best = (f64::INFINITY, [1.0, 0.0]);
    for i in 0..720 {
        let a = i as f64 * std::f64::consts::PI / 360.0;
        let theta = [a.cos() * 0.8, a.sin() * 0.8];
        let risk = sketch.estimate_risk(&theta);
        if risk < best.0 {
            best = (risk, theta);
        }
    }
    let (risk, theta) = best;
    let acc = accuracy(&theta, &xs, &ds.y);
    println!("best hyperplane normal = ({:+.3}, {:+.3})", theta[0], theta[1]);
    println!("estimated margin risk  = {risk:.4}");
    println!("training accuracy      = {:.1}%", acc * 100.0);
    assert!(acc > 0.85, "separable blobs should classify well");
}
