//! Online streaming scenario: a long-running sensor stream feeds the
//! sketch continuously; the model is re-trained periodically from the
//! *same* sketch, which keeps absorbing data between retrainings. Shows
//! the one-pass / anytime property: no example is ever stored.
//!
//! ```text
//! cargo run --release --example streaming_regression
//! ```

use storm::config::{OptimizerConfig, StormConfig};
use storm::data::scale::scale_to_unit_ball_quantile;
use storm::data::stream::{ResampleStream, StreamSource};
use storm::data::synthetic;
use storm::linalg::solve::{lstsq, mse, LstsqMethod};
use storm::optim::dfo::DfoOptimizer;
use storm::sketch::storm::StormSketch;
use storm::sketch::Sketch;

fn main() {
    // The "sensor": resamples an airfoil-like distribution indefinitely.
    let mut base = synthetic::airfoil(3);
    scale_to_unit_ball_quantile(&mut base, storm::data::scale::DEFAULT_RADIUS, 0.9);
    let theta_ls = lstsq(&base.x, &base.y, 0.0, LstsqMethod::Qr);
    let d = base.dim();
    let mut stream = ResampleStream::new(base.clone(), 99, 60_000);

    let cfg = StormConfig { rows: 1000, power: 4, saturating: true };
    let mut sketch = StormSketch::new(cfg, d + 1, 11);

    println!("streaming 60k examples; retraining from the sketch every 10k:");
    println!("{:>9} {:>12} {:>12} {:>10}", "examples", "storm_mse", "ls_mse", "param_err");
    let mut seen = 0u64;
    let retrain_every = 10_000;
    loop {
        let batch = stream.next_batch(512);
        if batch.is_empty() {
            break;
        }
        for z in &batch {
            sketch.insert(z);
        }
        let before = seen;
        seen += batch.len() as u64;
        if seen / retrain_every != before / retrain_every {
            let ocfg = OptimizerConfig {
                queries: 8,
                sigma: 0.3,
                step: 0.6,
                iters: 500,
                seed: seen, // fresh DFO path each retrain
            };
            let mut opt = DfoOptimizer::new(ocfg, d);
            let theta = opt.run(&sketch, ocfg.iters);
            println!(
                "{:>9} {:>12.4e} {:>12.4e} {:>10.3}",
                seen,
                mse(&base.x, &base.y, &theta),
                mse(&base.x, &base.y, &theta_ls),
                storm::metrics::relative_param_error(&theta, &theta_ls),
            );
        }
    }
    println!(
        "final sketch: {} examples in {} bytes (raw would be {} bytes)",
        sketch.count(),
        sketch.bytes(),
        sketch.count() as usize * (d + 1) * 8,
    );
}
