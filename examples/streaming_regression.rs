//! Online streaming scenario with delta synchronization: a long-running
//! sensor (the "device") sketches continuously and, at every sync epoch,
//! ships ONLY the counters that changed since the last epoch — an
//! epoch-tagged v2 wire delta — to a "server" sketch that the model
//! retrains from. Shows the one-pass / anytime property end to end: no
//! example is ever stored and the model improves while data keeps
//! arriving. The wire adapts to the round: a busy epoch (here, 10k
//! examples) touches nearly every counter, so the encoder takes the
//! dense fallback (~one v1 frame + 9 header bytes); a *quiet* epoch
//! (the 2-example trickle at the end) goes sparse and costs bytes
//! proportional to what actually changed.
//!
//! ```text
//! cargo run --release --example streaming_regression
//! ```

use storm::config::{OptimizerConfig, StormConfig};
use storm::data::scale::scale_to_unit_ball_quantile;
use storm::data::stream::{ResampleStream, StreamSource};
use storm::data::synthetic;
use storm::linalg::solve::{lstsq, mse, LstsqMethod};
use storm::optim::dfo::DfoOptimizer;
use storm::sketch::delta::SketchDelta;
use storm::sketch::serialize::{decode_delta, encode_delta, wire_bytes};
use storm::sketch::storm::StormSketch;

fn mode(delta: &SketchDelta) -> &'static str {
    if delta.populated_fraction() <= 0.5 {
        "sparse"
    } else {
        "dense"
    }
}

fn main() {
    // The "sensor": resamples an airfoil-like distribution indefinitely.
    let mut base = synthetic::airfoil(3);
    scale_to_unit_ball_quantile(&mut base, storm::data::scale::DEFAULT_RADIUS, 0.9);
    let theta_ls = lstsq(&base.x, &base.y, 0.0, LstsqMethod::Qr);
    let d = base.dim();
    let mut stream = ResampleStream::new(base.clone(), 99, 60_000);

    let cfg = StormConfig { rows: 1000, power: 4, saturating: true, ..Default::default() };
    // Device side: one long-lived sketch + the snapshot at the last sync.
    let mut device = StormSketch::new(cfg, d + 1, 11);
    let mut snap = device.snapshot();
    // Server side: rebuilt purely from wire deltas.
    let mut server = StormSketch::new(cfg, d + 1, 11);

    println!("streaming 60k examples; syncing a delta + retraining every 10k:");
    println!(
        "{:>6} {:>9} {:>11} {:>7} {:>12} {:>12} {:>10}",
        "epoch", "examples", "delta_bytes", "mode", "storm_mse", "ls_mse", "param_err"
    );
    let mut epoch = 0u64;
    let mut wire_total = 0usize;
    let sync_every = 10_000;
    let mut buf = Vec::new();
    loop {
        stream.next_batch_into(512, &mut buf);
        if buf.is_empty() {
            break;
        }
        device.insert_batch(&buf);
        if device.count() - snap.count() >= sync_every {
            // Ship only what changed since the last sync.
            let delta = device.delta_since(&snap, epoch);
            let frame = encode_delta(&delta);
            snap = device.snapshot();
            wire_total += frame.len();
            server.apply_delta(&decode_delta(&frame).expect("valid delta frame"));
            // Retrain from the server's sketch alone (anytime model).
            let ocfg = OptimizerConfig {
                queries: 8,
                sigma: 0.3,
                step: 0.6,
                iters: 500,
                seed: epoch + 1, // fresh DFO path each retrain
            };
            let mut opt = DfoOptimizer::new(ocfg, d);
            let theta = opt.run(&server, ocfg.iters);
            println!(
                "{:>6} {:>9} {:>11} {:>7} {:>12.4e} {:>12.4e} {:>10.3}",
                epoch,
                server.count(),
                frame.len(),
                mode(&delta),
                mse(&base.x, &base.y, &theta),
                mse(&base.x, &base.y, &theta_ls),
                storm::metrics::relative_param_error(&theta, &theta_ls),
            );
            epoch += 1;
        }
    }
    // Flush the tail so the server mirrors the device exactly
    // (counter-bit-identical, rebuilt from wire frames alone).
    let tail = device.delta_since(&snap, epoch);
    if !tail.is_empty() {
        let frame = encode_delta(&tail);
        println!("  tail sync: {} examples, {} bytes ({})", tail.count, frame.len(), mode(&tail));
        wire_total += frame.len();
        server.apply_delta(&decode_delta(&frame).expect("valid delta frame"));
        snap = device.snapshot();
        epoch += 1;
    }
    // A QUIET epoch: the sensor trickles 2 examples before the timer
    // fires. Only ~4 counters per row changed, so the delta goes sparse
    // — a fraction of the dense frame a full-sketch sync would cost.
    let mut trickle = ResampleStream::new(base.clone(), 123, 2);
    trickle.next_batch_into(2, &mut buf);
    device.insert_batch(&buf);
    let quiet = device.delta_since(&snap, epoch);
    let quiet_frame = encode_delta(&quiet);
    println!(
        "  quiet sync: {} examples, {} bytes ({}) vs {} bytes for a dense v1 frame",
        quiet.count,
        quiet_frame.len(),
        mode(&quiet),
        wire_bytes(&cfg),
    );
    wire_total += quiet_frame.len();
    server.apply_delta(&decode_delta(&quiet_frame).expect("valid delta frame"));
    assert_eq!(server.count(), device.count());
    assert_eq!(server.grid().counts_u32(), device.grid().counts_u32());
    println!(
        "device sketched {} examples; server mirrored them bit-exactly from {} delta bytes \
         (raw data would have been {} bytes)",
        device.count(),
        wire_total,
        device.count() as usize * (d + 1) * 8,
    );
}
