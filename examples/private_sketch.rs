//! Differential privacy demo: release the sketch with example-level
//! epsilon-DP (Laplace count noise) and measure the accuracy cost across
//! an epsilon sweep. The device keeps its exact counters; only the noisy
//! release leaves the device.
//!
//! ```text
//! cargo run --release --example private_sketch
//! ```

use storm::config::{OptimizerConfig, StormConfig};
use storm::data::scale::scale_to_unit_ball_quantile;
use storm::data::synthetic;
use storm::linalg::solve::{lstsq, mse, LstsqMethod};
use storm::optim::dfo::DfoOptimizer;
use storm::optim::FnOracle;
use storm::sketch::privacy::PrivateStormRelease;
use storm::sketch::storm::StormSketch;
use storm::util::mathx::norm2;

fn main() {
    let mut ds = synthetic::autos(21);
    scale_to_unit_ball_quantile(&mut ds, storm::data::scale::DEFAULT_RADIUS, 0.9);
    let d = ds.dim();
    let theta_ls = lstsq(&ds.x, &ds.y, 0.0, LstsqMethod::Qr);
    let cfg = StormConfig { rows: 300, power: 4, saturating: true, ..Default::default() };
    let mut sketch = StormSketch::new(cfg, d + 1, 5);
    for i in 0..ds.len() {
        sketch.insert(&ds.augmented(i));
    }

    let rescale = |q: &[f64]| -> Vec<f64> {
        let n = norm2(q);
        let r = storm::data::scale::query_radius();
        if n <= r { q.to_vec() } else { q.iter().map(|v| v * r / n).collect() }
    };
    let train = |risk: &dyn Fn(&[f64]) -> f64, seed: u64| -> Vec<f64> {
        let oracle = FnOracle::new(d, risk);
        let ocfg = OptimizerConfig { queries: 8, sigma: 0.3, step: 0.6, iters: 300, seed };
        DfoOptimizer::new(ocfg, d).run(&oracle, ocfg.iters)
    };

    println!("dataset autos (159 x 26), sketch {} bytes, ls mse {:.4e}", sketch.bytes(), mse(&ds.x, &ds.y, &theta_ls));
    println!("{:>8} {:>12} {:>12}", "epsilon", "mse", "vs_exact");
    let theta_exact = train(&|q: &[f64]| sketch.estimate_risk_scaled(q), 1);
    let mse_exact = mse(&ds.x, &ds.y, &theta_exact);
    for eps in [0.1, 0.5, 1.0, 5.0, 10.0] {
        let release = PrivateStormRelease::release(&sketch, eps, 33);
        let theta = train(&|q: &[f64]| release.estimate_risk(&rescale(q)), 1);
        let m = mse(&ds.x, &ds.y, &theta);
        println!("{eps:>8} {m:>12.4e} {:>11.2}x", m / mse_exact.max(1e-300));
    }
    println!("{:>8} {mse_exact:>12.4e} {:>11.2}x   (non-private sketch)", "inf", 1.0);
}
