//! Quickstart: sketch a dataset, train a linear model from the sketch
//! alone, and compare against exact least squares.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use storm::config::{OptimizerConfig, StormConfig};
use storm::data::scale::scale_to_unit_ball_quantile;
use storm::data::synthetic;
use storm::linalg::solve::{lstsq, mse, LstsqMethod};
use storm::optim::dfo::DfoOptimizer;
use storm::sketch::storm::StormSketch;

fn main() {
    // 1. A dataset (Table-1 substitute: airfoil, 1400 x 9).
    let mut ds = synthetic::airfoil(42);
    // 2. Unit-ball scaling — required by the asymmetric inner-product LSH.
    scale_to_unit_ball_quantile(&mut ds, storm::data::scale::DEFAULT_RADIUS, 0.9);

    // 3. One-pass sketching: every example updates 2 counters per row and
    //    is then forgotten. The sketch is the ONLY thing training sees.
    let cfg = StormConfig { rows: 400, power: 4, saturating: true, ..Default::default() };
    let mut sketch = StormSketch::new(cfg, ds.dim() + 1, 7);
    for i in 0..ds.len() {
        sketch.insert(&ds.augmented(i));
    }
    println!(
        "sketched {} examples into {} bytes ({}x smaller than the raw data)",
        ds.len(),
        sketch.bytes(),
        ds.raw_bytes() / sketch.bytes()
    );

    // 4. Derivative-free training against the sketch (Algorithm 2).
    let ocfg = OptimizerConfig { queries: 8, sigma: 0.3, step: 0.6, iters: 400, seed: 3 };
    let mut opt = DfoOptimizer::new(ocfg, ds.dim());
    let theta = opt.run(&sketch, ocfg.iters);

    // 5. Compare with exact least squares on the full data.
    let theta_ls = lstsq(&ds.x, &ds.y, 0.0, LstsqMethod::Qr);
    let zero = vec![0.0; ds.dim()];
    println!("training MSE:");
    println!("  zero model      {:.4e}", mse(&ds.x, &ds.y, &zero));
    println!("  STORM (sketch)  {:.4e}", mse(&ds.x, &ds.y, &theta));
    println!("  exact LS (full) {:.4e}", mse(&ds.x, &ds.y, &theta_ls));
    println!("theta (storm) = {theta:?}");
    println!("theta (ls)    = {theta_ls:?}");
}
