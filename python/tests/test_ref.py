"""Invariants of the pure-jnp reference oracle itself — these pin down the
specification the Pallas kernels and the rust scalar path both implement."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


def rand_ball(rng, n, d, radius=0.9):
    x = rng.normal(size=(n, d))
    norms = np.linalg.norm(x, axis=1, keepdims=True)
    r = radius * rng.uniform(size=(n, 1)) ** (1.0 / d)
    return (x / np.maximum(norms, 1e-12) * r).astype(np.float32)


def rand_planes(rng, rows, power, dim):
    return rng.normal(size=(rows, power, dim + 2)).astype(np.float32)


def test_augmentation_preserves_inner_product_and_norm():
    rng = np.random.default_rng(0)
    z = rand_ball(rng, 20, 5)
    q = rand_ball(rng, 20, 5)
    az = np.asarray(ref.augment_data(jnp.asarray(z)))
    aq = np.asarray(ref.augment_query(jnp.asarray(q)))
    # Unit norm after augmentation.
    np.testing.assert_allclose(np.linalg.norm(az, axis=1), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(aq, axis=1), 1.0, atol=1e-5)
    # Cross inner products preserved.
    np.testing.assert_allclose(
        np.sum(aq * az, axis=1), np.sum(q * z, axis=1), atol=1e-5
    )


def test_buckets_pack_bits_lsb_first():
    # proj bits [>=0] weighted 2^j, j over the last (power) axis.
    proj = jnp.asarray([[1.0, -1.0, 1.0, 1.0]])  # rows=2, power=2
    b = np.asarray(ref.buckets_from_projections(proj, rows=2, power=2))
    assert b.shape == (1, 2)
    assert b[0, 0] == 1  # bits (1, 0) -> 1
    assert b[0, 1] == 3  # bits (1, 1) -> 3


def test_sign_zero_counts_as_positive():
    proj = jnp.asarray([[0.0]])
    b = np.asarray(ref.buckets_from_projections(proj, rows=1, power=1))
    assert b[0, 0] == 1


def test_insert_counts_total_is_2n_per_row():
    rng = np.random.default_rng(1)
    z = rand_ball(rng, 33, 4)
    mask = np.ones(33, dtype=np.float32)
    planes = rand_planes(rng, 7, 3, 4)
    counts = np.asarray(
        ref.prp_insert_counts_ref(jnp.asarray(z), jnp.asarray(mask), jnp.asarray(planes))
    )
    assert counts.shape == (7, 8)
    np.testing.assert_allclose(counts.sum(axis=1), 2 * 33, atol=1e-4)


def test_mask_zeroes_padding():
    rng = np.random.default_rng(2)
    z = rand_ball(rng, 10, 3)
    planes = rand_planes(rng, 5, 2, 3)
    mask_full = np.ones(10, dtype=np.float32)
    mask_half = mask_full.copy()
    mask_half[5:] = 0.0
    c_half = np.asarray(
        ref.prp_insert_counts_ref(jnp.asarray(z), jnp.asarray(mask_half), jnp.asarray(planes))
    )
    c_first5 = np.asarray(
        ref.prp_insert_counts_ref(
            jnp.asarray(z[:5]), jnp.asarray(mask_full[:5]), jnp.asarray(planes)
        )
    )
    np.testing.assert_allclose(c_half, c_first5, atol=1e-5)


def test_query_normalization():
    # Single example, query landing where we can compute by hand: risk =
    # mean_r count[r, bucket_r] / n / 2.
    rng = np.random.default_rng(3)
    z = rand_ball(rng, 50, 3)
    planes = rand_planes(rng, 11, 4, 3)
    mask = np.ones(50, dtype=np.float32)
    counts = ref.prp_insert_counts_ref(jnp.asarray(z), jnp.asarray(mask), jnp.asarray(planes))
    q = rand_ball(rng, 4, 3)
    risks = np.asarray(
        ref.storm_query_ref(counts, jnp.asarray(q), jnp.asarray(planes), jnp.asarray([50.0]))
    )
    assert risks.shape == (4,)
    assert np.all(risks >= 0.0)
    # Bound: counts per bucket <= 2n, so risk <= 1.
    assert np.all(risks <= 1.0 + 1e-6)


def test_query_estimates_match_expected_loss_statistically():
    # With many rows, the estimate approaches the closed-form surrogate:
    # g(q, z) averaged over data (PRP collision probability).
    rng = np.random.default_rng(4)
    d = 3
    z = rand_ball(rng, 100, d, radius=0.8)
    q = rand_ball(rng, 1, d, radius=0.7)
    rows, power = 3000, 4
    planes = rand_planes(rng, rows, power, d)
    mask = np.ones(100, dtype=np.float32)
    counts = ref.prp_insert_counts_ref(jnp.asarray(z), jnp.asarray(mask), jnp.asarray(planes))
    risk = float(
        np.asarray(
            ref.storm_query_ref(counts, jnp.asarray(q), jnp.asarray(planes), jnp.asarray([100.0]))
        )[0]
    )
    t = z @ q[0]
    f = 1.0 - np.arccos(np.clip(t, -1, 1)) / np.pi
    g = 0.5 * f**power + 0.5 * (1.0 - f) ** power
    want = float(g.mean())
    assert abs(risk - want) < 0.02, (risk, want)
