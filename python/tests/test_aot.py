"""AOT pipeline tests: the lowered HLO text must parse back through the
XLA client (the same parser family the rust runtime uses) and execute with
numerics matching the jit path; the manifest must describe every file."""

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot, model


def test_to_hlo_text_roundtrips_through_xla_parser(tmp_path):
    dim, rows, power, batch = 4, 6, 3, 8
    z = jax.ShapeDtypeStruct((batch, dim), jnp.float32)
    mask = jax.ShapeDtypeStruct((batch,), jnp.float32)
    planes = jax.ShapeDtypeStruct((rows, power, dim + 2), jnp.float32)
    text = aot.to_hlo_text(model.prp_insert, z, mask, planes)
    assert "HloModule" in text
    # Parse back (same code path class the rust loader uses).
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_emitted_hlo_declares_expected_shapes(tmp_path):
    # The HLO text must advertise exactly the parameter/result shapes the
    # rust runtime builds literals for. (Numerical parity of the executed
    # artifact against the rust scalar path is asserted end-to-end by
    # rust/tests/integration_runtime.rs.)
    dim, rows, power, batch = 3, 5, 2, 8
    z_s = jax.ShapeDtypeStruct((batch, dim), jnp.float32)
    mask_s = jax.ShapeDtypeStruct((batch,), jnp.float32)
    planes_s = jax.ShapeDtypeStruct((rows, power, dim + 2), jnp.float32)
    text = aot.to_hlo_text(model.prp_insert, z_s, mask_s, planes_s)
    assert f"f32[{batch},{dim}]" in text            # z
    assert f"f32[{batch}]" in text                  # mask
    assert f"f32[{rows},{power},{dim + 2}]" in text  # planes
    assert f"f32[{rows},{1 << power}]" in text      # counts output
    # Output is a 1-tuple (return_tuple=True) — the rust side un-tuples.
    assert "ENTRY" in text


def test_emit_writes_manifest_and_files(tmp_path):
    # Shrink the config list for test speed.
    orig = aot.CONFIGS
    aot.CONFIGS = [("tiny", 3, 4, 2, 8, 4)]
    try:
        aot.emit(str(tmp_path))
    finally:
        aot.CONFIGS = orig
    files = sorted(os.listdir(tmp_path))
    assert "manifest.toml" in files
    assert "prp_insert_tiny.hlo.txt" in files
    assert "storm_query_tiny.hlo.txt" in files
    body = (tmp_path / "manifest.toml").read_text()
    assert "[artifact.prp_insert_tiny]" in body
    assert 'kind = "insert"' in body
    assert "dim = 3" in body
    assert "batch = 8" in body
    assert "queries = 4" in body
    # Every referenced file exists.
    for line in body.splitlines():
        if line.startswith("file = "):
            fname = line.split('"')[1]
            assert (tmp_path / fname).exists()
