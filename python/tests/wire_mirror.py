"""Independent Python mirror of the rust wire encoder (sketch/serialize.rs).

Used to cross-compute the golden byte fixtures checked into the rust test
suite: the fixtures in ``rust/src/sketch/serialize.rs`` must equal what
this mirror produces, so a drift in either implementation fails loudly.
Run directly to print every fixture:

    python3 python/tests/wire_mirror.py

The mirror is deliberately dependency-free and structured like the wire
spec, not like the rust code.
"""

import struct

# Wire-constant table. `stormlint` (tools/stormlint) extracts every
# ALL_CAPS assignment below and diffs it against both the Rust codec
# (rust/src/sketch/serialize.rs) and its own embedded snapshot — renaming
# or re-valuing any of these without updating all three sides fails lint.
MAGIC = 0x53544F52
VERSION_DENSE = 1  # v1: full dense u32 sketch
VERSION_DELTA = 2  # v2: epoch-tagged u32 delta
VERSION_WIDTH = 3  # v3: width/task/family/privacy-tagged delta
FLAG_DENSE = 0
FLAG_SPARSE = 1
FLAG_TASK_CLASSIFICATION = 2
FLAG_PRIVATE = 16
FAMILY_SHIFT = 2
FAMILY_MASK = 0b11 << FAMILY_SHIFT
FAMILY_DENSE = 0
FAMILY_SPARSE = 1
FAMILY_HADAMARD = 2
HEADER = 4 + 2 + 2 + 4 + 4 + 8 + 8  # magic..count, all versions
HEADER_V2 = HEADER + 8 + 1  # + epoch + flags
HEADER_V3 = HEADER + 8 + 1 + 1  # + epoch + width + flags
MAX_CELLS = 1 << 26  # decoder allocation ceiling (rows * buckets)


def fnv1a(data: bytes) -> int:
    h = 0x811C9DC5
    for b in data:
        h ^= b
        h = (h * 0x01000193) & 0xFFFFFFFF
    return h


def varint(v: int) -> bytes:
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v == 0:
            out.append(b)
            return bytes(out)
        out.append(b | 0x80)


def header(version, power, rows, dim, seed, count) -> bytes:
    return struct.pack("<IHHIIQQ", MAGIC, version, power, rows, dim, seed, count)


def encode_v1(power, rows, dim, seed, count, counts) -> bytes:
    body = header(VERSION_DENSE, power, rows, dim, seed, count)
    body += b"".join(struct.pack("<I", c) for c in counts)
    return body + struct.pack("<I", fnv1a(body))


def encode_delta(
    power,
    rows,
    dim,
    seed,
    count,
    epoch,
    counts,
    width_bytes=4,
    classification=False,
    family=0,
    density_permille=None,
    private=False,
) -> bytes:
    """v2 (u32 dense-family regression) or v3 (narrow width,
    classification, a structured hash family, and/or a private delta).

    ``family`` is the 2-bit code in flags bits 2-3 (0 = dense, 1 = sparse
    Rademacher, 2 = Hadamard); the sparse family appends its density
    per-mille as a little-endian u16 right after the flags byte.
    ``private`` sets flags bit 4 (DP-noised increments) and forces v3.
    """
    v3 = width_bytes != 4 or classification or family != FAMILY_DENSE or private
    body = header(VERSION_WIDTH if v3 else VERSION_DELTA, power, rows, dim, seed, count)
    body += struct.pack("<Q", epoch)
    if v3:
        body += bytes([width_bytes])
    tag_bits = 0
    if v3:
        tag_bits = (
            (FLAG_TASK_CLASSIFICATION if classification else 0)
            | (family << FAMILY_SHIFT)
            | (FLAG_PRIVATE if private else 0)
        )
    density = struct.pack("<H", density_permille) if (v3 and family == FAMILY_SPARSE) else b""
    nonzero = [(i, c) for i, c in enumerate(counts) if c != 0]
    if len(nonzero) * 2 <= len(counts):  # populated fraction <= 50%
        body += bytes([FLAG_SPARSE | tag_bits]) + density
        body += varint(len(nonzero))
        prev = None
        for i, c in nonzero:
            body += varint(i if prev is None else i - prev)
            body += varint(c)
            prev = i
    else:
        body += bytes([FLAG_DENSE | tag_bits]) + density
        fmt = {1: "<B", 2: "<H", 4: "<I"}[width_bytes]
        body += b"".join(struct.pack(fmt, c) for c in counts)
    return body + struct.pack("<I", fnv1a(body))


# The checked-in fixture shapes (see serialize.rs golden_* constructors).
SPARSE = dict(
    power=2, rows=2, dim=3, seed=0x1122334455667788, count=5, epoch=7,
    counts=[0, 3, 0, 1, 0, 0, 0, 2],
)
DENSE = dict(
    power=2, rows=2, dim=2, seed=0x0807060504030201, count=11, epoch=9,
    counts=[1, 2, 3, 4, 5, 6, 0, 7],
)
DENSE_U16 = dict(
    power=2, rows=2, dim=2, seed=0x0807060504030201, count=11, epoch=9,
    counts=[1, 300, 3, 4, 5, 6, 0, 700],
)


def encode_v3_u32_regression(spec) -> bytes:
    """The explicit v3-at-u32 regression frame (rust encode_delta_v3;
    the implicit encoder ships u32 regression deltas as v2 instead)."""
    body = header(VERSION_WIDTH, spec["power"], spec["rows"], spec["dim"], spec["seed"], spec["count"])
    body += struct.pack("<Q", spec["epoch"])
    body += bytes([4])
    nonzero = [(i, c) for i, c in enumerate(spec["counts"]) if c != 0]
    assert len(nonzero) * 2 <= len(spec["counts"])
    body += bytes([FLAG_SPARSE])
    body += varint(len(nonzero))
    prev = None
    for i, c in nonzero:
        body += varint(i if prev is None else i - prev)
        body += varint(c)
        prev = i
    return body + struct.pack("<I", fnv1a(body))


def fixtures():
    """Every golden fixture checked into rust/src/sketch/serialize.rs,
    keyed by its Rust constant name."""
    s, d, d16 = SPARSE, DENSE, DENSE_U16
    return {
        "GOLDEN_V1_DENSE_HEX": encode_v1(
            s["power"], s["rows"], s["dim"], s["seed"], s["count"], s["counts"]
        ),
        "GOLDEN_V2_SPARSE_HEX": encode_delta(**s),
        "GOLDEN_V2_DENSE_HEX": encode_delta(**d),
        "GOLDEN_V3_U8_SPARSE_HEX": encode_delta(**s, width_bytes=1),
        "GOLDEN_V3_U16_DENSE_HEX": encode_delta(**d16, width_bytes=2),
        "GOLDEN_V3_U32_SPARSE_HEX": encode_v3_u32_regression(s),
        # Classifier deltas: same logical grids, task bit set (always v3).
        "GOLDEN_CLF_U8_SPARSE_HEX": encode_delta(**s, width_bytes=1, classification=True),
        "GOLDEN_CLF_U16_DENSE_HEX": encode_delta(**d16, width_bytes=2, classification=True),
        "GOLDEN_CLF_U32_SPARSE_HEX": encode_delta(**s, width_bytes=4, classification=True),
        # Structured hash families: family bits 2-3 set (always v3); the
        # sparse family carries its density per-mille after the flags.
        "GOLDEN_SPARSE_FAM_U32_SPARSE_HEX": encode_delta(
            **s, family=FAMILY_SPARSE, density_permille=250
        ),
        "GOLDEN_HADAMARD_U8_SPARSE_HEX": encode_delta(
            **s, width_bytes=1, family=FAMILY_HADAMARD
        ),
        "GOLDEN_SPARSE_FAM_CLF_U16_DENSE_HEX": encode_delta(
            **d16, width_bytes=2, classification=True,
            family=FAMILY_SPARSE, density_permille=100
        ),
        # Private deltas: flags bit 4 set (always v3, even u32 regression).
        "GOLDEN_PRIVATE_U32_SPARSE_HEX": encode_delta(**s, private=True),
        "GOLDEN_PRIVATE_U8_SPARSE_HEX": encode_delta(**s, width_bytes=1, private=True),
        "GOLDEN_PRIVATE_CLF_U16_DENSE_HEX": encode_delta(
            **d16, width_bytes=2, classification=True, private=True
        ),
    }


if __name__ == "__main__":
    for name, data in fixtures().items():
        print(f"{name} = {data.hex()}")
