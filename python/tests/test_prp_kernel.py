"""L1 Pallas kernels vs the pure-jnp oracle, including hypothesis sweeps
over shapes — the core correctness signal for the compiled hot path."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import prp, ref


def rand_ball(rng, n, d, radius=0.9):
    x = rng.normal(size=(n, d))
    norms = np.linalg.norm(x, axis=1, keepdims=True)
    r = radius * rng.uniform(size=(n, 1)) ** (1.0 / d)
    return (x / np.maximum(norms, 1e-12) * r).astype(np.float32)


def test_matmul_project_matches_jnp_exact_shape():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(prp.TILE_B, 12)).astype(np.float32)
    w = rng.normal(size=(12, 40)).astype(np.float32)
    got = np.asarray(prp.matmul_project(jnp.asarray(x), jnp.asarray(w)))
    want = x @ w
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=300),
    a=st.integers(min_value=1, max_value=24),
    m=st.integers(min_value=1, max_value=48),
)
def test_matmul_project_shape_sweep(b, a, m):
    # Padding to the batch tile must be invisible to callers.
    rng = np.random.default_rng(b * 1000 + a * 10 + m)
    x = rng.normal(size=(b, a)).astype(np.float32)
    w = rng.normal(size=(a, m)).astype(np.float32)
    got = np.asarray(prp.matmul_project(jnp.asarray(x), jnp.asarray(w)))
    assert got.shape == (b, m)
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)


def test_onehot_histogram_matches_numpy():
    rng = np.random.default_rng(1)
    b, rows, nb = 50, 6, 8
    buckets = rng.integers(0, nb, size=(b, rows)).astype(np.int32)
    mask = (rng.uniform(size=b) > 0.3).astype(np.float32)
    got = np.asarray(
        prp.onehot_histogram(jnp.asarray(buckets), jnp.asarray(mask), nb)
    )
    want = np.zeros((rows, nb), dtype=np.float32)
    for i in range(b):
        if mask[i] > 0:
            for r in range(rows):
                want[r, buckets[i, r]] += 1.0
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=80),
    rows=st.integers(min_value=1, max_value=12),
    power=st.integers(min_value=1, max_value=5),
)
def test_onehot_histogram_shape_sweep(b, rows, power):
    nb = 1 << power
    rng = np.random.default_rng(b * 100 + rows * 10 + power)
    buckets = rng.integers(0, nb, size=(b, rows)).astype(np.int32)
    mask = np.ones(b, dtype=np.float32)
    got = np.asarray(prp.onehot_histogram(jnp.asarray(buckets), jnp.asarray(mask), nb))
    assert got.shape == (rows, nb)
    # Every row's histogram must total b.
    np.testing.assert_allclose(got.sum(axis=1), b, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=64),
    d=st.integers(min_value=1, max_value=10),
    rows=st.integers(min_value=1, max_value=8),
    power=st.integers(min_value=1, max_value=4),
)
def test_full_insert_pipeline_vs_ref(b, d, rows, power):
    from compile import model

    rng = np.random.default_rng(b * 997 + d * 31 + rows * 7 + power)
    z = rand_ball(rng, b, d)
    mask = (rng.uniform(size=b) > 0.2).astype(np.float32)
    planes = rng.normal(size=(rows, power, d + 2)).astype(np.float32)
    got = np.asarray(
        model.prp_insert(jnp.asarray(z), jnp.asarray(mask), jnp.asarray(planes))
    )
    want = np.asarray(
        ref.prp_insert_counts_ref(jnp.asarray(z), jnp.asarray(mask), jnp.asarray(planes))
    )
    np.testing.assert_allclose(got, want, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=20),
    d=st.integers(min_value=1, max_value=10),
    rows=st.integers(min_value=1, max_value=8),
    power=st.integers(min_value=1, max_value=4),
)
def test_full_query_pipeline_vs_ref(k, d, rows, power):
    from compile import model

    rng = np.random.default_rng(k * 13 + d * 101 + rows * 3 + power)
    nb = 1 << power
    counts = rng.integers(0, 50, size=(rows, nb)).astype(np.float32)
    q = rand_ball(rng, k, d)
    planes = rng.normal(size=(rows, power, d + 2)).astype(np.float32)
    n = jnp.asarray([123.0])
    got = np.asarray(
        model.storm_query(jnp.asarray(counts), jnp.asarray(q), jnp.asarray(planes), n)
    )
    want = np.asarray(
        ref.storm_query_ref(jnp.asarray(counts), jnp.asarray(q), jnp.asarray(planes), n)
    )
    assert got.shape == (k,)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
