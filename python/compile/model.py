"""L2: the STORM compute graphs, composed from the L1 Pallas kernels.

Two jit-able entry points mirror the rust runtime's interface exactly
(see rust/src/runtime/executor.rs):

* `prp_insert(z, mask, planes)`       -> counts delta [R, 2^P]
* `storm_query(counts, q, planes, n)` -> surrogate risks [K]

Hyperplanes are *inputs* (not baked constants) so the rust coordinator
feeds the very same hash family its scalar path uses — counters agree
bit-for-bit between backends, which the integration tests assert.

Python never runs at serving time: `aot.py` lowers these functions once
to HLO text and the rust PJRT runtime executes the artifacts.
"""

import jax.numpy as jnp

from .kernels import prp as kernels
from .kernels import ref


def prp_insert(z, mask, planes):
    """Batch PRP insert: the counts delta for a (padded) example batch.

    z:      [B, D] f32 augmented examples, unit-ball scaled
    mask:   [B]    f32 1.0 = real row, 0.0 = padding
    planes: [R, P, D+2] f32

    Returns [R, 2^P] f32 — add-mergeable with the live sketch.
    """
    rows, power, _ = planes.shape
    w = planes.reshape(rows * power, -1).T  # [D+2, R*P]
    # L1 projection kernel over both PRP arms. aug(z) and aug(-z) share
    # the tail coordinate, so negation happens before augmentation.
    apos = ref.augment_data(z)
    aneg = ref.augment_data(-z)
    proj_pos = kernels.matmul_project(apos, w)  # [B, R*P]
    proj_neg = kernels.matmul_project(aneg, w)
    bpos = ref.buckets_from_projections(proj_pos, rows, power)  # [B, R]
    bneg = ref.buckets_from_projections(proj_neg, rows, power)
    nb = 1 << power
    # L1 histogram kernel (one-hot contraction per sketch row).
    cpos = kernels.onehot_histogram(bpos, mask, nb)
    cneg = kernels.onehot_histogram(bneg, mask, nb)
    return cpos + cneg


def storm_query(counts, q, planes, n):
    """Risk query: estimate the surrogate risk at each candidate.

    counts: [R, 2^P] f32 live counters
    q:      [K, D]   f32 queries, unit-ball scaled
    planes: [R, P, D+2] f32
    n:      [1]      f32 total examples ingested

    Returns [K] f32 risks (mean bucket count / n / SCALE) — identical
    normalization to rust `StormSketch::estimate_risk`.
    """
    rows, power, _ = planes.shape
    w = planes.reshape(rows * power, -1).T
    aq = ref.augment_query(q)
    proj = kernels.matmul_project(aq, w)  # [K, R*P]
    buckets = ref.buckets_from_projections(proj, rows, power)  # [K, R]
    nb = 1 << power
    onehot = jnp.equal(
        buckets[..., None], jnp.arange(nb, dtype=jnp.int32)[None, None, :]
    ).astype(counts.dtype)
    gathered = jnp.einsum("krb,rb->kr", onehot, counts)
    mean_count = jnp.mean(gathered, axis=-1)
    return mean_count / jnp.maximum(n[0], 1.0) / ref.SCALE
