"""Pure-jnp reference oracle for the STORM kernels.

Everything here is the *specification*: straightforward, unfused jnp that
mirrors the rust scalar implementation bit-for-bit in structure. The
Pallas kernels in `prp.py` and the L2 graphs in `model.py` are tested
against these functions by `python/tests/`.

Conventions (shared with the rust side — see rust/src/lsh/):

* data-side augmentation:  z -> [z, 0, sqrt(1 - |z|^2)]
* query-side augmentation: q -> [q, sqrt(1 - |q|^2), 0]
* a p-bit SRP bucket packs bit j = (proj_j >= 0) as 2^j
* PRP inserts both z and -z; a query reads one bucket per row
* normalized query estimate = mean_r counts[r, bucket_r] / n, and the
  paper's surrogate risk is that divided by SCALE = 2.
"""

import jax.numpy as jnp

# Normalization constant relating raw counts to the surrogate loss g
# (mirrors rust sketch::storm::SCALE).
SCALE = 2.0


def augment_data(z):
    """Data-side MIPS augmentation. z: [B, D] inside the unit ball."""
    sq = jnp.sum(z * z, axis=-1, keepdims=True)
    tail = jnp.sqrt(jnp.maximum(0.0, 1.0 - sq))
    zeros = jnp.zeros_like(tail)
    return jnp.concatenate([z, zeros, tail], axis=-1)


def augment_query(q):
    """Query-side MIPS augmentation. q: [K, D] inside the unit ball."""
    sq = jnp.sum(q * q, axis=-1, keepdims=True)
    tail = jnp.sqrt(jnp.maximum(0.0, 1.0 - sq))
    zeros = jnp.zeros_like(tail)
    return jnp.concatenate([q, tail, zeros], axis=-1)


def buckets_from_projections(proj, rows, power):
    """Pack sign bits into bucket ids.

    proj: [N, rows * power] raw projection values. Bit j of a row's bucket
    is (proj >= 0), weighted 2^j — identical to the rust SRP tie-break.
    Returns int32 [N, rows].
    """
    n = proj.shape[0]
    bits = (proj >= 0.0).astype(jnp.int32).reshape(n, rows, power)
    weights = (2 ** jnp.arange(power, dtype=jnp.int32))[None, None, :]
    return jnp.sum(bits * weights, axis=-1)


def prp_insert_counts_ref(z, mask, planes):
    """Reference PRP batch insert.

    z:      [B, D]   augmented examples (inside unit ball)
    mask:   [B]      1.0 for real rows, 0.0 for padding
    planes: [R, P, D+2] hyperplanes (shared with the rust hash family)

    Returns counts delta [R, 2^P] (f32): for every real example, +1 at
    bucket(l_r(z)) and +1 at bucket(l_r(-z)) per row.
    """
    rows, power, _ = planes.shape
    w = planes.reshape(rows * power, -1)  # [R*P, D+2]
    apos = augment_data(z)                # [B, D+2]
    aneg = augment_data(-z)
    proj_pos = apos @ w.T                 # [B, R*P]
    proj_neg = aneg @ w.T
    bpos = buckets_from_projections(proj_pos, rows, power)  # [B, R]
    bneg = buckets_from_projections(proj_neg, rows, power)
    nb = 1 << power
    # Cast BEFORE adding: the two PRP arms can land in the same bucket
    # (tail-dominated rows), and bool + bool would OR instead of count 2.
    onehot_pos = jnp.equal(bpos[..., None], jnp.arange(nb)[None, None, :]).astype(jnp.float32)
    onehot_neg = jnp.equal(bneg[..., None], jnp.arange(nb)[None, None, :]).astype(jnp.float32)
    m = mask[:, None, None]
    counts = jnp.sum((onehot_pos + onehot_neg) * m, axis=0)  # [R, nb]
    return counts.astype(jnp.float32)


def storm_query_ref(counts, q, planes, n):
    """Reference STORM risk query.

    counts: [R, 2^P] f32 counters
    q:      [K, D]   query vectors (inside unit ball)
    planes: [R, P, D+2]
    n:      [1]      examples ingested

    Returns [K] surrogate risks: mean_r counts[r, bucket_r(q)] / n / SCALE.
    """
    rows, power, _ = planes.shape
    w = planes.reshape(rows * power, -1)
    aq = augment_query(q)                 # [K, D+2]
    proj = aq @ w.T                       # [K, R*P]
    b = buckets_from_projections(proj, rows, power)  # [K, R]
    nb = 1 << power
    onehot = jnp.equal(b[..., None], jnp.arange(nb)[None, None, :]).astype(counts.dtype)
    gathered = jnp.einsum("krb,rb->kr", onehot, counts)  # [K, R]
    mean_count = jnp.mean(gathered, axis=-1)
    return mean_count / jnp.maximum(n[0], 1.0) / SCALE
