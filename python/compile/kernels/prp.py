"""L1 Pallas kernels for the STORM hot spot.

Two kernels:

* `matmul_sign` — the projection core: a tiled `[B, A] @ [A, M]` matmul
  producing raw projection values. On TPU this is the MXU workload; the
  batch dimension is tiled through VMEM via BlockSpec while the (small)
  plane matrix stays resident.
* `onehot_hist` — histogram-by-matmul: for each sketch row, build the
  one-hot encoding of the batch's bucket ids and contract it with the
  mask. This replaces the CPU formulation's scatter-increment with two
  dense passes — the standard TPU trick (scatter is memory-bound and
  serializes; one-hot contraction runs on the MXU).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
scalar edge CPUs; there is no warp/shared-memory structure to port.
Instead the *bulk* insert path (the leader / simulation hot loop) is
reformulated as MXU-shaped dense algebra: batch-tile in VMEM, planes
resident, scatter -> one-hot matmul.

Both kernels are lowered with `interpret=True` — the CPU PJRT plugin
cannot execute Mosaic custom-calls; numerics are identical and the TPU
analysis lives in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile for the projection kernel. 128 matches the MXU systolic edge.
TILE_B = 128


def _matmul_sign_kernel(x_ref, w_ref, o_ref):
    """One batch tile: o = x @ w (f32)."""
    o_ref[...] = x_ref[...] @ w_ref[...]


def matmul_project(x, w):
    """Tiled projection `x @ w` via Pallas.

    x: [B, A] (augmented examples or queries)
    w: [A, M] (transposed plane matrix, M = R * P)
    Returns [B, M] raw projections (f32).
    """
    b, a = x.shape
    a2, m = w.shape
    assert a == a2, f"inner dims mismatch: {a} vs {a2}"
    # Pad the batch to a tile multiple so the grid is rectangular.
    pad = (-b) % TILE_B
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    bp = x.shape[0]
    out = pl.pallas_call(
        _matmul_sign_kernel,
        grid=(bp // TILE_B,),
        in_specs=[
            pl.BlockSpec((TILE_B, a), lambda i: (i, 0)),
            pl.BlockSpec((a, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, m), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))
    return out[:b]


def _onehot_hist_kernel(buckets_ref, mask_ref, o_ref, *, num_buckets):
    """One sketch row: counts[b] = sum_i mask[i] * [buckets[i] == b].

    buckets_ref: [B, 1] f32 bucket ids for this row
    mask_ref:    [B, 1] f32 weights
    o_ref:       [1, num_buckets] f32 counts
    """
    ids = buckets_ref[...]  # [B, 1]
    iota = jax.lax.broadcasted_iota(jnp.float32, (1, num_buckets), 1)
    onehot = (ids == iota).astype(jnp.float32)  # [B, num_buckets]
    o_ref[...] = mask_ref[...].T @ onehot  # [1, B] @ [B, nb] -> [1, nb]


def onehot_histogram(buckets, mask, num_buckets):
    """Per-row histogram of bucket ids via one-hot contraction.

    buckets: [B, R] int32
    mask:    [B]    f32
    Returns [R, num_buckets] f32 counts.
    """
    b, rows = buckets.shape
    kernel = functools.partial(_onehot_hist_kernel, num_buckets=num_buckets)
    out = pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((b, 1), lambda r: (0, r)),
            pl.BlockSpec((b, 1), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, num_buckets), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, num_buckets), jnp.float32),
        interpret=True,
    )(buckets.astype(jnp.float32), mask.astype(jnp.float32)[:, None])
    return out
